"""Batched serving example: continuous batching over a fixed slot pool.

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 6 --slots 2
"""

import argparse
import time

import jax
import numpy as np

from repro.config import ServeConfig, get_arch
from repro.models import build_model
from repro.serve import ServeEngine, greedy_generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    # simple batched greedy first
    prompt = jax.numpy.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8))
    )
    out = greedy_generate(model, params, prompt, steps=8)
    print(f"[serve_lm] greedy_generate -> {np.asarray(out).tolist()}")

    # continuous batching: more requests than slots
    eng = ServeEngine(
        model, params, ServeConfig(max_batch=args.slots, max_seq=128)
    )
    rng = np.random.default_rng(1)
    t0 = time.perf_counter()
    rids = [
        eng.submit(rng.integers(0, cfg.vocab_size, size=rng.integers(3, 10)),
                   max_new=args.max_new)
        for _ in range(args.requests)
    ]
    results = eng.run()
    dt = time.perf_counter() - t0
    for rid in rids:
        print(f"[serve_lm] request {rid}: {results[rid]}")
    total_tokens = sum(len(v) for v in results.values())
    print(f"[serve_lm] {len(results)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s on 1 CPU core)")


if __name__ == "__main__":
    main()
