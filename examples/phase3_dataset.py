"""Phase III end-to-end: sweep → sharded dataset → LM training.

The paper's pipeline exists so that "researchers can generate massive
datasets from their simulations" (§2.10) and feed them to ML. This example
runs the whole chain on one machine:

1. a fault-tolerant *recording* sweep (mixed scenarios, grouped dispatch,
   injected node failure) streams per-instance time series + token streams
   into npz/jsonl shards via ``repro.data.shards.DatasetWriter``;
2. the sharded dataset is reloaded and inspected;
3. a small LM trains a few steps on the shard-backed token corpus
   (``sim_token_batches(shard_dir=...)``).

Run:  PYTHONPATH=src python examples/phase3_dataset.py
CI runs it with ``--quick`` as the scenario-smoke job's Phase-III check.
"""

from __future__ import annotations

import argparse
import tempfile

from repro.config import TrainConfig, get_arch
from repro.core.aggregate import aggregate_metrics
from repro.core.fault import FailureInjector, run_with_failures
from repro.core.record import RecordConfig
from repro.core.scenario import SimConfig
from repro.core.sweep import SweepConfig, SweepRunner
from repro.core.tokens import vocab_size
from repro.data import sim_token_batches
from repro.data.shards import DatasetWriter, ShardedDataset
from repro.models import build_model
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--instances", type=int, default=8)
    ap.add_argument("--steps", type=int, default=240)
    ap.add_argument("--chunk-steps", type=int, default=80)
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--record-every", type=int, default=10)
    ap.add_argument("--record-slots", type=int, default=8)
    ap.add_argument("--train-steps", type=int, default=20)
    ap.add_argument("--dataset-dir", default=None,
                    help="default: a fresh temp directory")
    ap.add_argument("--quick", action="store_true",
                    help="CI-grade sizes (fewer steps everywhere)")
    args = ap.parse_args()
    if args.quick:
        args.steps, args.chunk_steps, args.train_steps = 120, 60, 5

    root = args.dataset_dir or tempfile.mkdtemp(prefix="phase3_")

    # ---- 1. recording sweep → shards (with an injected node failure) ----
    sim = SimConfig(n_slots=args.slots)
    cfg = SweepConfig(
        n_instances=args.instances,
        steps_per_instance=args.steps,
        chunk_steps=args.chunk_steps,
        sim=sim,
        scenario_mix=("highway_merge", "lane_drop"),
        dispatch="grouped",
        record=RecordConfig(record_every=args.record_every,
                            k_slots=args.record_slots),
    )
    runner = SweepRunner(cfg)
    writer = DatasetWriter(root, cfg, shard_size=4)
    injector = FailureInjector(n_workers=4, plan={0: [1]})
    state, info = run_with_failures(runner, injector, writer=writer)
    summary = aggregate_metrics(state.metrics, state.scenario_id,
                                cfg.scenarios)
    manifest = writer.finalize(summary=summary, fault_info=info)
    print(f"[phase3] sweep complete: {info['completion_rate']*100:.0f}% "
          f"({len(info['failure_events'])} failure events survived)")
    print(f"[phase3] dataset: {manifest}")

    # ---- 2. reload + inspect the sharded dataset ----
    ds = ShardedDataset.load(root)
    fields, series, valid = ds.series()
    corpus = ds.token_corpus()
    assert ds.n_instances == args.instances, "dataset must cover every instance"
    print(f"[phase3] {ds.n_instances} instances in "
          f"{len(ds.manifest['shards'])} shards | series {series.shape} "
          f"({', '.join(fields)}) | corpus {corpus.shape[0]} tokens")

    # ---- 3. train a small LM on the shard-backed corpus ----
    model_cfg = get_arch("qwen1.5-0.5b").reduced(
        d_model=128, n_heads=2, n_kv_heads=2, head_dim=64, d_ff=512,
        n_layers=2, vocab_size=max(vocab_size(sim), 128),
    )
    model = build_model(model_cfg)
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=2,
                     total_steps=args.train_steps, schedule="cosine")
    data = sim_token_batches(model_cfg, sim, batch=4, seq=64, shard_dir=root)
    trainer = Trainer(model, tc, data, log_every=max(args.train_steps // 2, 1))
    trainer.run(steps=args.train_steps)
    ce0, ce1 = trainer.history[0]["ce"], trainer.history[-1]["ce"]
    print(f"[phase3] trained {args.train_steps} steps on sweep shards: "
          f"ce {ce0:.3f} -> {ce1:.3f}")


if __name__ == "__main__":
    main()
