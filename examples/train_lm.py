"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

This is the assignment's (b) end-to-end example. Default config is a
~100M-param qwen-family model on learnable synthetic sequences; pass
``--data sim`` to train on simulation-derived tokens instead (Phase III),
or pick any of the 10 assigned architectures with ``--arch``.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse

import jax

from repro.config import TrainConfig, get_arch
from repro.core.scenario import SimConfig
from repro.data import sim_token_batches, synthetic_batches
from repro.models import build_model
from repro.train.trainer import Trainer
from repro.launch.roofline import param_counts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--n-layers", type=int, default=8)
    ap.add_argument("--data", choices=["synthetic", "sim"],
                    default="synthetic")
    ap.add_argument("--shard-dir", default=None,
                    help="train on a sharded Phase-III dataset directory "
                         "(written by repro.launch.sweep --dataset-dir; "
                         "implies --data sim)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    if args.shard_dir:
        args.data = "sim"

    base = get_arch(args.arch)
    pat = len(base.layer_pattern)
    cfg = base.reduced(
        d_model=args.d_model,
        n_heads=max(args.d_model // 64, 1),
        n_kv_heads=max(args.d_model // 64, 1),
        head_dim=64,
        d_ff=args.d_model * 4,
        lru_width=args.d_model,
        n_layers=max(args.n_layers // pat, 1) * pat,
        vocab_size=8192,
    )
    model = build_model(cfg)
    n = param_counts(cfg)
    print(f"[train_lm] {cfg.name}: ~{n['total_with_emb']/1e6:.1f}M params "
          f"({n['total']/1e6:.1f}M non-embedding)")

    tc = TrainConfig(
        learning_rate=1e-3, warmup_steps=20, total_steps=args.steps,
        schedule="cosine",
    )
    if args.data == "sim":
        data = sim_token_batches(
            cfg, SimConfig(n_slots=32), batch=args.batch, seq=args.seq,
            shard_dir=args.shard_dir,
        )
    else:
        data = synthetic_batches(cfg, batch=args.batch, seq=args.seq)
    trainer = Trainer(model, tc, data, ckpt_dir=args.ckpt_dir, log_every=20)
    trainer.run(steps=args.steps)
    print(f"[train_lm] final ce={trainer.history[-1]['ce']:.4f} "
          f"({trainer.history[-1]['steps_per_s']:.2f} it/s)")


if __name__ == "__main__":
    main()
