"""Quickstart: the Webots.HPC pipeline end-to-end in one minute on CPU.

1. Run a randomized simulation sweep (the paper's highway-merge workload —
   swap ``scenario=`` for any registry name: lane_drop, stop_and_go,
   speed_limit_zone, or your own; see repro.core.scenarios).
2. Aggregate the output dataset (paper §2.10 "big data" phase).
3. Tokenize trajectories and train a small LM on them (Phase III).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.config import TrainConfig, get_arch
from repro.core.aggregate import aggregate_metrics
from repro.core.scenario import SimConfig
from repro.core.sweep import SweepConfig, SweepRunner, completion_rate
from repro.data import sim_token_batches
from repro.models import build_model
from repro.train.trainer import Trainer


def main() -> None:
    # ---- 1. simulation sweep (a small paper-style job array) -------------
    sim = SimConfig(n_slots=32, scenario="highway_merge")
    sweep = SweepConfig(
        n_instances=8, steps_per_instance=600, chunk_steps=200, sim=sim,
        seed=42,
    )
    print("== sweep: 8 randomized merge simulations, 60 sim-seconds each ==")
    runner = SweepRunner(sweep)
    state = runner.run()
    print(f"completion rate: {completion_rate(state)*100:.0f}%")

    # ---- 2. aggregate the output dataset ---------------------------------
    summary = aggregate_metrics(
        state.metrics, scenario_ids=state.scenario_id,
        scenario_names=sweep.scenarios,
    )
    print("== aggregated dataset ==")
    for k, v in summary.items():
        print(f"  {k}: {v}")

    # ---- 3. train a reduced LM on simulation tokens (Phase III) ---------
    print("== training qwen1.5-0.5b (reduced) on sim tokens ==")
    cfg = get_arch("qwen1.5-0.5b").reduced(vocab_size=256)
    model = build_model(cfg)
    tc = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=60,
                     schedule="cosine")
    data = sim_token_batches(cfg, sim, batch=8, seq=64, n_instances=4)
    trainer = Trainer(model, tc, data, log_every=20)
    trainer.run(steps=60)
    first, last = trainer.history[0]["ce"], trainer.history[-1]["ce"]
    print(f"ce: {first:.3f} -> {last:.3f} (model is learning sim structure)")


if __name__ == "__main__":
    main()
