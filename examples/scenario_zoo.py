"""Scenario zoo: one small sweep per registered scenario, side by side.

The Scenario API (``repro.core.scenarios``) separates the vectorized physics
core from pluggable workload definitions, so "run every workload we have"
is a loop over the registry — no per-scenario simulator forks. This example
sweeps each registered scenario with a handful of randomized instances and
prints the per-scenario dataset digest (with each scenario's own metric
names), then runs all of them again as ONE mixed sweep compiled into a
single program.

Run:  PYTHONPATH=src python examples/scenario_zoo.py
"""

from repro.core.aggregate import aggregate_metrics
from repro.core.scenario import SimConfig
from repro.core.scenarios import get_scenario, list_scenarios
from repro.core.sweep import SweepConfig, SweepRunner, completion_rate

INSTANCES = 6
STEPS = 600


def sweep_one(name: str) -> dict:
    cfg = SweepConfig(
        n_instances=INSTANCES, steps_per_instance=STEPS, chunk_steps=200,
        sim=SimConfig(n_slots=32, scenario=name), seed=17,
    )
    state = SweepRunner(cfg).run()
    assert completion_rate(state) == 1.0
    summary = aggregate_metrics(
        state.metrics, scenario_ids=state.scenario_id,
        scenario_names=cfg.scenarios,
    )
    return summary["per_scenario"][name]


def main() -> None:
    print(f"== scenario zoo: {INSTANCES} instances x {STEPS} steps each ==")
    for name in list_scenarios():
        scn = get_scenario(name)
        geom = scn.geometry(SimConfig(n_slots=32))
        s = sweep_one(name)
        shape = (
            f"{geom.n_lanes} lanes"
            + (f" + {geom.special_lane}" if geom.special_lane != "none" else "")
            + (" (ring)" if geom.ring else "")
        )
        print(f"\n-- {name} [{shape}] --")
        for k, v in s.items():
            print(f"   {k}: {v:.3f}" if isinstance(v, float) else f"   {k}: {v}")

    print("\n== the same zoo as ONE mixed sweep (single compile) ==")
    cfg = SweepConfig(
        n_instances=2 * len(list_scenarios()), steps_per_instance=STEPS,
        chunk_steps=200, sim=SimConfig(n_slots=32), seed=23,
        scenario_mix=tuple(list_scenarios()),
    )
    state = SweepRunner(cfg).run()
    summary = aggregate_metrics(
        state.metrics, scenario_ids=state.scenario_id,
        scenario_names=cfg.scenarios,
    )
    print(f"completion: {completion_rate(state)*100:.0f}%")
    for name, s in summary["per_scenario"].items():
        print(f"  {name}: throughput={s.get('total_throughput', s.get('total_exited'))} "
              f"mean_speed={s['mean_speed']:.1f} collisions={s['total_collisions']}")


if __name__ == "__main__":
    main()
