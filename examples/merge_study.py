"""Merge study: the paper's Phase-II experiment in miniature.

Sweeps CAV penetration (the randomized parameter the paper's dataset was
built to explore) and reports how merge throughput / safety respond — the
kind of insight the paper's Phase III extracts with ML, read here directly
from the aggregated sweep dataset.

Run:  PYTHONPATH=src python examples/merge_study.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scenario import SimConfig, ScenarioParams
from repro.core.simulator import rollout


def run_point(p_cav: float, n_seeds: int = 4, steps: int = 900):
    cfg = SimConfig(n_slots=48)
    outs = []
    for s in range(n_seeds):
        key = jax.random.fold_in(jax.random.key(7), s)
        sp = ScenarioParams(
            lambda_main=jnp.array([0.35, 0.35, 0.35]),
            lambda_ramp=jnp.asarray(0.25),
            p_cav=jnp.asarray(p_cav),
            v0_mean=jnp.asarray(30.0),
            v0_ramp=jnp.asarray(21.0),
            seed=jnp.asarray(s, jnp.uint32),
        )
        m = rollout(key, cfg, sp, steps)
        outs.append(m)
    tp = np.mean([int(m.throughput) for m in outs])
    merges = np.mean([int(m.merges_ok) for m in outs])
    blocked = np.mean([int(m.ramp_blocked_steps) for m in outs])
    speed = np.mean(
        [float(m.speed_sum) / max(float(m.speed_count), 1) for m in outs]
    )
    return tp, merges, blocked, speed


def main() -> None:
    print(f"{'p_cav':>6} {'throughput':>11} {'merges':>7} "
          f"{'ramp_blocked':>13} {'mean_speed':>11}")
    for p_cav in [0.0, 0.25, 0.5, 0.75, 1.0]:
        tp, merges, blocked, speed = run_point(p_cav)
        print(f"{p_cav:>6.2f} {tp:>11.1f} {merges:>7.1f} "
              f"{blocked:>13.1f} {speed:>11.2f}")
    print("\nHigher CAV share → tighter accepted gaps → more completed "
          "merges per ramp demand (the Phase-II/III hypothesis).")


if __name__ == "__main__":
    main()
