"""Paper Tables 5.2/5.3, Figure 5.2 — parallel (6×8) vs serial (6×1) setups.

The paper ran 48 instances either 8-at-a-time per node or 1-at-a-time per
node and compared walltime / CPU time / throughput, finding the parallel
configuration ~sizably higher throughput despite slightly longer per-run
walltime. The accelerator-native analogue: one 48-wide vmapped batch
("6×8") vs eight sequential 6-wide batches ("6×1") over identical work.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.scenario import SimConfig, sample_scenario_params
from repro.core.simulator import rollout

STEPS = 400
N = 48


def run() -> None:
    cfg = SimConfig(n_slots=32)

    def one(i):
        k = jax.random.fold_in(jax.random.key(3), i)
        sp = sample_scenario_params(jax.random.fold_in(k, 1), cfg)
        return rollout(k, cfg, sp, STEPS)

    parallel = jax.jit(lambda: jax.vmap(one)(jnp.arange(N)))

    def serial():
        outs = []
        f = jax.jit(lambda ids: jax.vmap(one)(ids))
        for c in range(8):
            outs.append(f(jnp.arange(c * 6, (c + 1) * 6)))
        return outs

    tp = timeit(lambda: parallel())
    ts = timeit(serial, warmup=1, iters=2)
    emit(
        "fig5.2_parallel_6x8", tp * 1e6,
        f"throughput={N/tp:.2f}_sims_per_s per_sim_walltime={tp/N*1e3:.1f}ms",
    )
    emit(
        "fig5.2_serial_6x1", ts * 1e6,
        f"throughput={N/ts:.2f}_sims_per_s "
        f"parallel_speedup={ts/tp:.2f}x (paper: parallel wins unless "
        f"memory-bound)",
    )
