"""Paper Table 5.1 / Figure 5.1 — sample simulation throughput.

Two layers of validation:
1. **Schedule accounting** — reproduce the paper's exact numbers: 48·t
   completed runs per 15-minute slice on the cluster vs a 9.73-min/run
   personal computer, 2304 vs 74 after 12 h (31× speedup).
2. **Measured vectorization** — on this host, one simulation instance vs a
   48-wide vmapped batch (the per-node 8× of the paper collapses into the
   batch axis on an accelerator): veh-steps/s and the batch-over-serial
   speedup, plus the projected 12-hour run count for the paper's cluster.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.metrics import (
    PAPER_CLUSTER,
    PAPER_PC,
    PAPER_TIMESTAMPS,
    ClusterSpec,
    cluster_timeline,
    personal_timeline,
    speedup_at,
)
from repro.core.scenario import SimConfig, sample_scenario_params
from repro.core.simulator import rollout

STEPS = 600
N_BATCH = 48


def run() -> None:
    # ---- 1. schedule accounting vs the paper's published numbers --------
    spec = ClusterSpec()  # 6 nodes x 8 instances, 15-min walltime
    cluster = cluster_timeline(spec, PAPER_TIMESTAMPS)
    pc = personal_timeline(720 / 74, PAPER_TIMESTAMPS)
    match_cluster = cluster == PAPER_CLUSTER
    # the paper's PC column is an empirical (slightly non-uniform) rate;
    # the constant-rate model must track within ±3 and hit 74 at 12 h
    track_pc = (
        all(abs(a - b) <= 3 for a, b in zip(pc, PAPER_PC)) and pc[-1] == 74
    )
    speedup = speedup_at(spec, 720 / 74, 720.0)
    emit(
        "table5.1_schedule_accounting", 0.0,
        f"cluster_timeline_match={match_cluster} pc_tracks±3={track_pc} "
        f"speedup_12h={speedup:.1f}x (paper: ~31x)",
    )

    # ---- 2. measured single vs vmapped-batch throughput -----------------
    cfg = SimConfig(n_slots=48)

    def one(i):
        k = jax.random.fold_in(jax.random.key(0), i)
        sp = sample_scenario_params(jax.random.fold_in(k, 1), cfg)
        return rollout(k, cfg, sp, STEPS)

    single = jax.jit(lambda: one(0))
    batched = jax.jit(lambda: jax.vmap(one)(jnp.arange(N_BATCH)))

    t1 = timeit(lambda: single())
    tn = timeit(lambda: batched())
    per_instance_serial = t1
    per_instance_batched = tn / N_BATCH
    speedup = per_instance_serial / per_instance_batched
    sim_seconds = STEPS * cfg.dt
    emit(
        "fig5.1_single_instance", t1 * 1e6,
        f"sim_rate={sim_seconds/t1:.1f}x_realtime",
    )
    emit(
        "fig5.1_vmapped_batch48", tn * 1e6,
        f"per_instance={per_instance_batched*1e6:.0f}us "
        f"vectorization_speedup={speedup:.1f}x "
        f"runs_per_12h_this_host={int(12*3600/ (tn / N_BATCH)):,}",
    )

    # ---- 3. neighborhood engine: per-implementation step rate ----------
    # "reference" is the per-query O(N²) scan family the seed used; dense /
    # sort / pallas are the fused engine paths (repro.core.neighbors).
    impls = ["reference", "dense", "sort"]
    if jax.default_backend() == "tpu":
        impls.append("pallas")   # interpret mode off-TPU is not a timing
    for n_slots in (48, 128, 512):
        base = None
        for impl in impls:
            icfg = SimConfig(n_slots=n_slots, neighbor_impl=impl)
            isp = sample_scenario_params(jax.random.key(1), icfg)
            # key passed at call time so XLA cannot constant-fold the run
            roll = jax.jit(
                lambda k, icfg=icfg, isp=isp: rollout(k, icfg, isp, STEPS)
            )
            t = timeit(roll, jax.random.key(0))
            base = t if base is None else base
            emit(
                f"neighbor_{impl}_slots{n_slots}", t * 1e6,
                f"{STEPS/t:.0f}_steps_per_s "
                f"{STEPS*n_slots/t:.0f}_veh_steps_per_s "
                f"speedup_vs_reference={base/t:.2f}x",
            )
