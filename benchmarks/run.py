"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--only <name>]

Benchmarks:
- throughput          — paper Table 5.1 / Fig 5.1 (cluster vs PC)
- distribution        — paper §5.2 / Table 5.2 (evenness, completion, LPT)
- parallel_vs_serial  — paper Tables 5.2/5.3 / Fig 5.2 (6×8 vs 6×1)
- kernels             — hot-spot layers (tiled attention, simulator step)
- roofline            — §Roofline table from dry-run artifacts
- sweep               — steps/sec per scenario × neighbor engine + mixed
                        switch-vs-grouped dispatch suite (writes
                        BENCH_sweep.json for cross-PR tracking; CI's
                        bench-gate diffs a quick run against it —
                        SWEEP_BENCH_QUICK / SWEEP_BENCH_OUT env knobs)
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    distribution,
    kernels_bench,
    parallel_vs_serial,
    roofline_bench,
    sweep_bench,
    throughput,
)

SUITES = {
    "throughput": throughput.run,
    "distribution": distribution.run,
    "parallel_vs_serial": parallel_vs_serial.run,
    "kernels": kernels_bench.run,
    "roofline": roofline_bench.run,
    "sweep": sweep_bench.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(SUITES))
    args = ap.parse_args()
    names = [args.only] if args.only else list(SUITES)
    print("name,us_per_call,derived")
    failed = 0
    for name in names:
        try:
            SUITES[name]()
        except Exception:  # noqa: BLE001
            failed += 1
            traceback.print_exc()
            print(f"{name},0.0,FAILED")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
