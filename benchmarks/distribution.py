"""Paper §5.2 — instance distribution evenness + completion accounting.

The paper reports PBS placing exactly 8 instances on each of 6 nodes, 100 %
of the time, with 48·t datasets after t slices. Here: block (PBS-style)
assignment evenness, the same accounting under our sweep engine, and the
straggler-aware LPT assignment the paper's fixed scheduler lacks
(makespan under variable-cost instances — our beyond-paper improvement).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.metrics import (
    block_assignment,
    distribution_evenness,
    lpt_assignment,
    makespan,
)
from repro.core.scenario import SimConfig
from repro.core.sweep import SweepConfig, SweepRunner, completion_rate


def run() -> None:
    # ---- evenness of PBS-style block assignment (paper's case) ----------
    assign = block_assignment(48, 6)
    ev = distribution_evenness(assign, 6)
    emit(
        "table5.2_block_assignment", 0.0,
        f"counts={ev['counts']} perfectly_even={ev['perfectly_even']}",
    )

    # ---- completion accounting through the real sweep engine -------------
    cfg = SweepConfig(
        n_instances=12, steps_per_instance=300, chunk_steps=100,
        sim=SimConfig(n_slots=16), seed=1,
    )
    runner = SweepRunner(cfg)
    t = timeit(lambda: runner.run(), warmup=0, iters=1)
    state = runner.run()
    emit(
        "sec5.2_sweep_completion", t * 1e6,
        f"completion={completion_rate(state)*100:.0f}% "
        f"chunks={int(state.chunk)} (paper: 100%)",
    )

    # ---- straggler-aware assignment (beyond paper) ------------------------
    rng = np.random.default_rng(0)
    costs = rng.uniform(0.3, 1.0, size=48)  # variable-horizon instances
    m_block = makespan(costs, block_assignment(48, 6), 6)
    m_lpt = makespan(costs, lpt_assignment(costs, 6), 6)
    emit(
        "beyond_lpt_straggler_assignment", 0.0,
        f"block_makespan={m_block:.2f} lpt_makespan={m_lpt:.2f} "
        f"improvement={(m_block/m_lpt-1)*100:.1f}%",
    )
