"""Shared benchmark helpers."""

from __future__ import annotations

import time

import jax


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Best-of-iters seconds, blocking."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
