"""§Roofline — render the dry-run artifact table (reads experiments/dryrun).

Not a timing benchmark: summarizes the compiled-artifact roofline terms per
(arch × shape × mesh) cell produced by ``repro.launch.dryrun``.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")


def load_cells(mesh: str = "16x16") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        with open(path) as f:
            c = json.load(f)
        if c.get("mesh") == mesh and "__" + mesh + ".json" in path:
            cells.append(c)
    return cells


def run() -> None:
    cells = load_cells()
    if not cells:
        emit("roofline_table", 0.0,
             "no dry-run artifacts found (run python -m repro.launch.dryrun)")
        return
    ok = [c for c in cells if c.get("status") == "ok"]
    skipped = [c for c in cells if c.get("status") == "skip"]
    worst = min(ok, key=lambda c: c["roofline"]["roofline_fraction"])
    best = max(ok, key=lambda c: c["roofline"]["roofline_fraction"])
    dominant = {}
    for c in ok:
        dominant[c["roofline"]["dominant"]] = (
            dominant.get(c["roofline"]["dominant"], 0) + 1
        )
    emit(
        "roofline_summary_16x16", 0.0,
        f"cells_ok={len(ok)} skipped={len(skipped)} dominant={dominant} "
        f"best={best['cell']}@{best['roofline']['roofline_fraction']:.3f} "
        f"worst={worst['cell']}@{worst['roofline']['roofline_fraction']:.4f}",
    )
    for c in ok:
        r = c["roofline"]
        emit(
            f"roofline[{c['cell']}]", 0.0,
            f"dom={r['dominant']} c/m/n="
            f"{r['compute_s']:.2e}/{r['memory_s']:.2e}/"
            f"{r['collective_s']:.2e}s frac={r['roofline_fraction']:.4f}",
        )
