"""Kernel-layer benchmarks: tiled-flash XLA path vs naive attention, and the
simulator physics step — the hot spots the Pallas kernels target.

(Pallas interpret-mode timings are meaningless on CPU; what is measurable
here is the *algorithmic* win of the tiled/windowed formulation, which
carries to the TPU kernels.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.models.attention import causal_mask, flash_xla, sdpa


def run() -> None:
    b, s, h, d = 1, 4096, 4, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.bfloat16)

    naive = jax.jit(
        lambda q, k, v: sdpa(q, k, v, causal_mask(s, s), d**-0.5)
    )
    tiled = jax.jit(
        lambda q, k, v: flash_xla(
            q, k, v, causal=True, window=0, scale=d**-0.5,
            tile_q=1024, tile_k=1024,
        )
    )
    windowed = jax.jit(
        lambda q, k, v: flash_xla(
            q, k, v, causal=True, window=512, scale=d**-0.5,
            tile_q=512, tile_k=512,
        )
    )
    tn = timeit(naive, q, k, v)
    tt = timeit(tiled, q, k, v)
    tw = timeit(windowed, q, k, v)
    emit("attn_naive_4k", tn * 1e6, "full-mask softmax attention")
    emit("attn_tiled_flash_4k", tt * 1e6,
         f"causal tile-skip speedup={tn/tt:.2f}x")
    emit("attn_windowed_512_4k", tw * 1e6,
         f"window-skip speedup={tn/tw:.2f}x (gemma2 local layers)")

    # simulator physics step throughput (the idm kernel's target)
    from repro.core.scenario import SimConfig, sample_scenario_params
    from repro.core.simulator import rollout

    cfg = SimConfig(n_slots=64)
    sp = sample_scenario_params(jax.random.key(1), cfg)
    roll = jax.jit(lambda k: rollout(k, cfg, sp, 500))
    tr = timeit(roll, jax.random.key(2))
    emit("sim_rollout_500steps_64veh", tr * 1e6,
         f"{500/tr:.0f}_steps_per_s {500*64/tr:.0f}_veh_steps_per_s")

    # neighborhood-engine table build: the fused pass that replaced the
    # ~8 independent O(N²) scans per sim_step (one build serves them all)
    from repro.core.neighbors import build_tables

    n_lanes_total = cfg.n_lanes + 1
    for n in (48, 128, 512):
        ks = jax.random.split(jax.random.key(3), 3)
        pos = jax.random.uniform(ks[0], (n,), jnp.float32, 0.0, 900.0)
        lane = jax.random.randint(ks[1], (n,), 0, n_lanes_total)
        active = jax.random.uniform(ks[2], (n,)) < 0.8
        base = None
        for impl in ("reference", "dense", "sort"):
            # inputs passed at call time so XLA cannot constant-fold them
            fn = jax.jit(
                lambda p, l, a, impl=impl: build_tables(
                    p, l, a, cfg.vehicle_len, n_lanes_total, impl
                )
            )
            t = timeit(fn, pos, lane, active)
            base = t if base is None else base
            emit(
                f"neighbor_tables_{impl}_n{n}", t * 1e6,
                f"per_lane_tables=[{n_lanes_total},{n}] "
                f"speedup_vs_reference={base/t:.2f}x",
            )
