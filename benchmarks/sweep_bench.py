"""Sweep benchmark: steps/sec per scenario × neighborhood engine.

Emits the usual ``name,us_per_call,derived`` CSV lines AND writes
``BENCH_sweep.json`` so the performance trajectory of every workload is
tracked from PR to PR (compare the file across commits). The measured
quantity is a jitted single-instance rollout (the unit the sweep vmaps),
per scenario and per neighbor engine implementation.

    PYTHONPATH=src python -m benchmarks.run --only sweep
"""

from __future__ import annotations

import json
import platform

import jax

from benchmarks.common import emit, timeit
from repro.core.scenario import SimConfig, sample_scenario_params
from repro.core.scenarios import list_scenarios
from repro.core.simulator import rollout

STEPS = 400
N_SLOTS = 48
OUT_PATH = "BENCH_sweep.json"


def run() -> None:
    impls = ["reference", "dense", "sort"]
    if jax.default_backend() == "tpu":
        impls.append("pallas")   # interpret mode off-TPU is not a timing

    results: dict[str, dict[str, dict[str, float]]] = {}
    for name in list_scenarios():
        results[name] = {}
        for impl in impls:
            cfg = SimConfig(n_slots=N_SLOTS, scenario=name,
                            neighbor_impl=impl)
            sp = sample_scenario_params(jax.random.key(1), cfg)
            # key passed at call time so XLA cannot constant-fold the run
            roll = jax.jit(
                lambda k, cfg=cfg, sp=sp: rollout(k, cfg, sp, STEPS)
            )
            t = timeit(roll, jax.random.key(0))
            steps_per_s = STEPS / t
            results[name][impl] = {
                "seconds_per_rollout": t,
                "steps_per_sec": steps_per_s,
                "veh_steps_per_sec": steps_per_s * N_SLOTS,
            }
            emit(
                f"sweep_{name}_{impl}", t * 1e6,
                f"{steps_per_s:.0f}_steps_per_s "
                f"{steps_per_s * N_SLOTS:.0f}_veh_steps_per_s",
            )

    payload = {
        "bench": "sweep",
        "steps": STEPS,
        "n_slots": N_SLOTS,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "platform": platform.platform(),
        "results": results,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    emit("sweep_json", 0.0, f"wrote_{OUT_PATH}")
