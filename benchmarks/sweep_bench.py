"""Sweep benchmark: steps/sec per scenario × neighborhood engine, plus the
``mixed`` suite timing switch-vs-grouped dispatch on multi-scenario sweeps.

Emits the usual ``name,us_per_call,derived`` CSV lines AND writes
``BENCH_sweep.json`` so the performance trajectory of every workload is
tracked from PR to PR (compare the file across commits; CI's bench-gate job
diffs a quick-mode run against the committed baseline). Two measured
quantities:

- per-scenario: a jitted single-instance rollout (the unit the sweep vmaps),
  per scenario and per neighbor engine implementation;
- mixed: wall time of a full ``SweepRunner.run_chunk`` on 2- and 4-scenario
  mixes under ``dispatch="switch"`` (vmapped lax.switch — every branch runs
  for every instance) vs ``dispatch="grouped"`` (per-scenario repacked
  calls), including the planner's host-side gather/scatter overhead. The
  ``speedup`` field is the headline: grouped recovers the k× switch tax.
- recording: ``run_chunk`` step rate with trajectory recording off vs on
  (``RecordConfig(record_every=10, k_slots=8)``) — the Phase-III dataset
  channel must stay cheap (< 15 % step-rate cost; CI's bench gate warns
  past that and fails past 30 %).

    PYTHONPATH=src python -m benchmarks.run --only sweep

Env knobs (for CI): ``SWEEP_BENCH_QUICK=1`` shrinks steps/slots/instances to
CI-grade cost; ``SWEEP_BENCH_OUT=path.json`` redirects the JSON (so a fresh
run can be diffed against the committed baseline without overwriting it).
"""

from __future__ import annotations

import json
import os
import platform

import jax

from benchmarks.common import emit, timeit
from repro.core.record import RecordConfig
from repro.core.scenario import SimConfig, sample_scenario_params
from repro.core.scenarios import list_scenarios
from repro.core.simulator import rollout
from repro.core.sweep import SweepConfig, SweepRunner

QUICK = os.environ.get("SWEEP_BENCH_QUICK", "") not in ("", "0")
STEPS = 120 if QUICK else 400
N_SLOTS = 16 if QUICK else 48
# quick runs default to the quick baseline file so reproducing CI locally
# can never clobber the committed full-scale trajectory
OUT_PATH = os.environ.get(
    "SWEEP_BENCH_OUT",
    "BENCH_sweep_quick.json" if QUICK else "BENCH_sweep.json",
)

MIXES = {
    "mix2": ("highway_merge", "lane_drop"),
    "mix4": ("highway_merge", "lane_drop", "stop_and_go", "speed_limit_zone"),
}
# the mixed suite keeps full instance/step scale even in quick mode: the
# dispatch comparison needs compute to dominate the per-call overhead or
# the grouped/switch ratio collapses into dispatch noise (slots still
# shrink, which is where the compile+step cost lives)
MIX_INSTANCES = 16
MIX_CHUNK_STEPS = 200


def _bench_scenarios(impls) -> dict:
    results: dict[str, dict[str, dict[str, float]]] = {}
    for name in list_scenarios():
        results[name] = {}
        for impl in impls:
            cfg = SimConfig(n_slots=N_SLOTS, scenario=name,
                            neighbor_impl=impl)
            sp = sample_scenario_params(jax.random.key(1), cfg)
            # key passed at call time so XLA cannot constant-fold the run
            roll = jax.jit(
                lambda k, cfg=cfg, sp=sp: rollout(k, cfg, sp, STEPS)
            )
            t = timeit(roll, jax.random.key(0))
            steps_per_s = STEPS / t
            results[name][impl] = {
                "seconds_per_rollout": t,
                "steps_per_sec": steps_per_s,
                "veh_steps_per_sec": steps_per_s * N_SLOTS,
            }
            emit(
                f"sweep_{name}_{impl}", t * 1e6,
                f"{steps_per_s:.0f}_steps_per_s "
                f"{steps_per_s * N_SLOTS:.0f}_veh_steps_per_s",
            )
    return results


def _bench_mixed() -> dict:
    """Time one run_chunk of a mixed sweep per dispatch mode.

    compaction is off so every call steps the full instance set (stable
    repeat timing: finished instances no-op at identical cost), and the
    measured delta is purely the dispatch strategy.
    """
    mixed: dict[str, dict] = {}
    for mix_name, mix in MIXES.items():
        entry: dict = {"scenarios": list(mix), "n_scenarios": len(mix),
                       "n_instances": MIX_INSTANCES,
                       "chunk_steps": MIX_CHUNK_STEPS}
        for dispatch in ("switch", "grouped"):
            cfg = SweepConfig(
                n_instances=MIX_INSTANCES,
                steps_per_instance=MIX_CHUNK_STEPS,
                chunk_steps=MIX_CHUNK_STEPS,
                sim=SimConfig(n_slots=N_SLOTS, neighbor_impl="sort"),
                scenario_mix=mix,
                compaction=False,
                dispatch=dispatch,
            )
            runner = SweepRunner(cfg)
            state = runner.init()
            # best-of-5: the dispatch comparison is a ratio, so it needs
            # more noise rejection than the absolute per-scenario numbers
            t = timeit(runner.run_chunk, state, iters=5)
            steps_per_s = MIX_CHUNK_STEPS * MIX_INSTANCES / t
            entry[dispatch] = {
                "seconds_per_chunk": t,
                "steps_per_sec": steps_per_s,
                "veh_steps_per_sec": steps_per_s * N_SLOTS,
            }
            emit(
                f"sweep_{mix_name}_{dispatch}", t * 1e6,
                f"{steps_per_s:.0f}_steps_per_s",
            )
        entry["speedup_grouped_over_switch"] = (
            entry["grouped"]["steps_per_sec"] / entry["switch"]["steps_per_sec"]
        )
        emit(f"sweep_{mix_name}_speedup", 0.0,
             f"{entry['speedup_grouped_over_switch']:.2f}x_grouped_over_switch")
        mixed[mix_name] = entry
    return mixed


def _bench_recording() -> dict:
    """Step-rate cost of the Phase-III recording channel.

    Same chunk workload with recording off vs RecordConfig(record_every=10,
    k_slots=8): the delta is the per-step channel extraction + the strided
    buffer scatter. compaction off for stable repeat timing, single
    scenario so the measurement isolates recording from dispatch.
    """
    base = dict(
        n_instances=MIX_INSTANCES,
        steps_per_instance=MIX_CHUNK_STEPS,
        chunk_steps=MIX_CHUNK_STEPS,
        sim=SimConfig(n_slots=N_SLOTS, neighbor_impl="sort"),
        compaction=False,
    )
    entry: dict = {"n_instances": MIX_INSTANCES,
                   "chunk_steps": MIX_CHUNK_STEPS,
                   "record_every": 10, "k_slots": 8}
    rates = {}
    for label, rec in (
        ("off", None),
        ("on", RecordConfig(record_every=10, k_slots=8)),
    ):
        runner = SweepRunner(SweepConfig(record=rec, **base))
        state = runner.init()
        t = timeit(runner.run_chunk, state, iters=5)
        rates[label] = MIX_CHUNK_STEPS * MIX_INSTANCES / t
        entry[label] = {
            "seconds_per_chunk": t,
            "steps_per_sec": rates[label],
            "veh_steps_per_sec": rates[label] * N_SLOTS,
        }
        emit(f"sweep_record_{label}", t * 1e6,
             f"{rates[label]:.0f}_steps_per_s")
    entry["overhead_frac"] = 1.0 - rates["on"] / rates["off"]
    emit("sweep_record_overhead", 0.0,
         f"{entry['overhead_frac']*100:.1f}pct_step_rate_cost")
    return entry


def run() -> None:
    impls = ["reference", "dense", "sort"]
    if jax.default_backend() == "tpu":
        impls.append("pallas")   # interpret mode off-TPU is not a timing

    results = _bench_scenarios(impls)
    mixed = _bench_mixed()
    recording = _bench_recording()

    payload = {
        "bench": "sweep",
        "steps": STEPS,
        "n_slots": N_SLOTS,
        "quick": QUICK,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "platform": platform.platform(),
        "results": results,
        "mixed": mixed,
        "recording": recording,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    emit("sweep_json", 0.0, f"wrote_{OUT_PATH}")
