"""Sweep benchmark: steps/sec per scenario × neighborhood engine, plus the
``mixed`` suite timing switch-vs-grouped dispatch on multi-scenario sweeps.

Emits the usual ``name,us_per_call,derived`` CSV lines AND writes
``BENCH_sweep.json`` so the performance trajectory of every workload is
tracked from PR to PR (compare the file across commits; CI's bench-gate job
diffs a quick-mode run against the committed baseline). Two measured
quantities:

- per-scenario: a jitted single-instance rollout (the unit the sweep vmaps),
  per scenario and per neighbor engine implementation;
- mixed: wall time of a full ``SweepRunner.run_chunk`` on 2- and 4-scenario
  mixes under ``dispatch="switch"`` (vmapped lax.switch — every branch runs
  for every instance) vs ``dispatch="grouped"`` (per-scenario repacked
  calls), including the planner's host-side gather/scatter overhead. The
  ``speedup`` field is the headline: grouped recovers the k× switch tax.
- recording: ``run_chunk`` step rate with trajectory recording off vs on
  (``RecordConfig(record_every=10, k_slots=8)``) — the Phase-III dataset
  channel must stay cheap (< 15 % step-rate cost; CI's bench gate warns
  past that and fails past 30 %).
- sharded: the device-sharded executor. Two measurements: per-chunk step
  rate at 1 device vs every device the backend exposes (instance-axis
  scaling — on forced-host CPU "devices" share the same cores, so this is
  a code-path check there and a real speedup on real hardware), and the
  wall time of a full recording sweep streaming shards to disk under the
  synchronous vs the **pipelined** loop (``run_with_failures
  pipeline=True``: chunk c+1 dispatched before chunk c's checkpoint/shard
  I/O). ``overlap_gain`` = sync/pipelined wall — the gate fails below
  0.9× (pipelining must never cost throughput) and the acceptance target
  is ≥ 1.0 at 4 simulated devices.

    PYTHONPATH=src python -m benchmarks.run --only sweep

Env knobs (for CI): ``SWEEP_BENCH_QUICK=1`` shrinks steps/slots/instances to
CI-grade cost; ``SWEEP_BENCH_OUT=path.json`` redirects the JSON (so a fresh
run can be diffed against the committed baseline without overwriting it).
"""

from __future__ import annotations

import json
import os
import platform

import jax

from benchmarks.common import emit, timeit
from repro.core.record import RecordConfig
from repro.core.scenario import SimConfig, sample_scenario_params
from repro.core.scenarios import list_scenarios
from repro.core.simulator import rollout
from repro.core.sweep import SweepConfig, SweepRunner

QUICK = os.environ.get("SWEEP_BENCH_QUICK", "") not in ("", "0")
STEPS = 120 if QUICK else 400
N_SLOTS = 16 if QUICK else 48
# quick runs default to the quick baseline file so reproducing CI locally
# can never clobber the committed full-scale trajectory
OUT_PATH = os.environ.get(
    "SWEEP_BENCH_OUT",
    "BENCH_sweep_quick.json" if QUICK else "BENCH_sweep.json",
)

MIXES = {
    "mix2": ("highway_merge", "lane_drop"),
    "mix4": ("highway_merge", "lane_drop", "stop_and_go", "speed_limit_zone"),
}
# the mixed suite keeps full instance/step scale even in quick mode: the
# dispatch comparison needs compute to dominate the per-call overhead or
# the grouped/switch ratio collapses into dispatch noise (slots still
# shrink, which is where the compile+step cost lives)
MIX_INSTANCES = 16
MIX_CHUNK_STEPS = 200


def _bench_scenarios(impls) -> dict:
    results: dict[str, dict[str, dict[str, float]]] = {}
    for name in list_scenarios():
        results[name] = {}
        for impl in impls:
            cfg = SimConfig(n_slots=N_SLOTS, scenario=name,
                            neighbor_impl=impl)
            sp = sample_scenario_params(jax.random.key(1), cfg)
            # key passed at call time so XLA cannot constant-fold the run
            roll = jax.jit(
                lambda k, cfg=cfg, sp=sp: rollout(k, cfg, sp, STEPS)
            )
            t = timeit(roll, jax.random.key(0))
            steps_per_s = STEPS / t
            results[name][impl] = {
                "seconds_per_rollout": t,
                "steps_per_sec": steps_per_s,
                "veh_steps_per_sec": steps_per_s * N_SLOTS,
            }
            emit(
                f"sweep_{name}_{impl}", t * 1e6,
                f"{steps_per_s:.0f}_steps_per_s "
                f"{steps_per_s * N_SLOTS:.0f}_veh_steps_per_s",
            )
    return results


def _bench_mixed() -> dict:
    """Time one run_chunk of a mixed sweep per dispatch mode.

    compaction is off so every call steps the full instance set (stable
    repeat timing: finished instances no-op at identical cost), and the
    measured delta is purely the dispatch strategy.
    """
    mixed: dict[str, dict] = {}
    for mix_name, mix in MIXES.items():
        entry: dict = {"scenarios": list(mix), "n_scenarios": len(mix),
                       "n_instances": MIX_INSTANCES,
                       "chunk_steps": MIX_CHUNK_STEPS}
        for dispatch in ("switch", "grouped"):
            cfg = SweepConfig(
                n_instances=MIX_INSTANCES,
                steps_per_instance=MIX_CHUNK_STEPS,
                chunk_steps=MIX_CHUNK_STEPS,
                sim=SimConfig(n_slots=N_SLOTS, neighbor_impl="sort"),
                scenario_mix=mix,
                compaction=False,
                dispatch=dispatch,
            )
            runner = SweepRunner(cfg)
            state = runner.init()
            # best-of-5: the dispatch comparison is a ratio, so it needs
            # more noise rejection than the absolute per-scenario numbers
            t = timeit(runner.run_chunk, state, iters=5)
            steps_per_s = MIX_CHUNK_STEPS * MIX_INSTANCES / t
            entry[dispatch] = {
                "seconds_per_chunk": t,
                "steps_per_sec": steps_per_s,
                "veh_steps_per_sec": steps_per_s * N_SLOTS,
            }
            emit(
                f"sweep_{mix_name}_{dispatch}", t * 1e6,
                f"{steps_per_s:.0f}_steps_per_s",
            )
        entry["speedup_grouped_over_switch"] = (
            entry["grouped"]["steps_per_sec"] / entry["switch"]["steps_per_sec"]
        )
        emit(f"sweep_{mix_name}_speedup", 0.0,
             f"{entry['speedup_grouped_over_switch']:.2f}x_grouped_over_switch")
        mixed[mix_name] = entry
    return mixed


def _bench_recording() -> dict:
    """Step-rate cost of the Phase-III recording channel.

    Same chunk workload with recording off vs RecordConfig(record_every=10,
    k_slots=8): the delta is the per-step channel extraction + the strided
    buffer scatter. compaction off for stable repeat timing, single
    scenario so the measurement isolates recording from dispatch.
    """
    base = dict(
        n_instances=MIX_INSTANCES,
        steps_per_instance=MIX_CHUNK_STEPS,
        chunk_steps=MIX_CHUNK_STEPS,
        sim=SimConfig(n_slots=N_SLOTS, neighbor_impl="sort"),
        compaction=False,
    )
    entry: dict = {"n_instances": MIX_INSTANCES,
                   "chunk_steps": MIX_CHUNK_STEPS,
                   "record_every": 10, "k_slots": 8}
    rates = {}
    for label, rec in (
        ("off", None),
        ("on", RecordConfig(record_every=10, k_slots=8)),
    ):
        runner = SweepRunner(SweepConfig(record=rec, **base))
        state = runner.init()
        t = timeit(runner.run_chunk, state, iters=5)
        rates[label] = MIX_CHUNK_STEPS * MIX_INSTANCES / t
        entry[label] = {
            "seconds_per_chunk": t,
            "steps_per_sec": rates[label],
            "veh_steps_per_sec": rates[label] * N_SLOTS,
        }
        emit(f"sweep_record_{label}", t * 1e6,
             f"{rates[label]:.0f}_steps_per_s")
    entry["overhead_frac"] = 1.0 - rates["on"] / rates["off"]
    emit("sweep_record_overhead", 0.0,
         f"{entry['overhead_frac']*100:.1f}pct_step_rate_cost")
    return entry


def _bench_sharded() -> dict:
    """Scaling + overlap of the device-sharded, pipelined executor.

    Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (CI
    does) or on a real multi-device host; with a single visible device the
    suite records only the 1-device numbers and no overlap comparison is
    possible, so it is marked ``skipped``.
    """
    import shutil
    import tempfile
    import time

    import numpy as np
    from jax.sharding import Mesh

    from repro.core.fault import FailureInjector, run_with_failures
    from repro.data.shards import DatasetWriter

    n_devices = jax.device_count()
    entry: dict = {"n_devices": n_devices, "n_instances": MIX_INSTANCES,
                   "chunk_steps": MIX_CHUNK_STEPS}
    if n_devices < 2:
        entry["skipped"] = "needs >= 2 devices (force with XLA_FLAGS)"
        emit("sweep_sharded", 0.0, "skipped_single_device")
        return entry

    base = dict(
        n_instances=MIX_INSTANCES,
        steps_per_instance=MIX_CHUNK_STEPS,
        chunk_steps=MIX_CHUNK_STEPS,
        sim=SimConfig(n_slots=N_SLOTS, neighbor_impl="sort"),
        scenario_mix=MIXES["mix2"],
        compaction=False,
        dispatch="grouped",
    )
    # instance-axis scaling: per-chunk step rate, 1 device vs all
    scaling = {}
    for label, mesh in (
        ("1", None),
        (str(n_devices), Mesh(np.asarray(jax.devices()), ("workers",))),
    ):
        runner = SweepRunner(SweepConfig(**base), mesh=mesh)
        state = runner.init()
        t = timeit(runner.run_chunk, state, iters=5)
        rate = MIX_CHUNK_STEPS * MIX_INSTANCES / t
        scaling[label] = {
            "seconds_per_chunk": t,
            "steps_per_sec": rate,
            "veh_steps_per_sec": rate * N_SLOTS,
        }
        emit(f"sweep_sharded_{label}dev", t * 1e6, f"{rate:.0f}_steps_per_s")
    entry["scaling"] = scaling
    entry["scaling_speedup"] = (
        scaling[str(n_devices)]["steps_per_sec"] / scaling["1"]["steps_per_sec"]
    )

    # compute/I-O overlap: full recording sweep streaming shards to disk,
    # synchronous vs pipelined loop (multiple chunks so the deferred-I/O
    # double buffer actually alternates)
    n_chunks = 4
    rec_cfg = SweepConfig(**{
        **base,
        "steps_per_instance": MIX_CHUNK_STEPS * n_chunks,
        "record": RecordConfig(record_every=10, k_slots=8),
        "vary_horizon": True,
        "min_horizon_frac": 0.4,
        "compaction": True,
    })
    mesh = Mesh(np.asarray(jax.devices()), ("workers",))
    runner = SweepRunner(rec_cfg, mesh=mesh)
    injector = FailureInjector(n_workers=n_devices, plan={})

    def one_run(pipeline: bool) -> float:
        root = tempfile.mkdtemp(prefix="sweep_sharded_bench_")
        try:
            writer = DatasetWriter(root, rec_cfg, shard_size=4)
            t0 = time.perf_counter()
            state, _ = run_with_failures(runner, injector, writer=writer,
                                         pipeline=pipeline)
            jax.block_until_ready(state.sim.t)
            writer.finalize()
            return time.perf_counter() - t0
        finally:
            shutil.rmtree(root, ignore_errors=True)

    one_run(False)  # warm the compile caches out of the measurement
    overlap = {}
    for label, pipeline in (("synchronous", False), ("pipelined", True)):
        best = min(one_run(pipeline) for _ in range(3))
        rate = MIX_CHUNK_STEPS * n_chunks * MIX_INSTANCES / best
        overlap[label] = {"seconds_per_sweep": best, "steps_per_sec": rate}
        emit(f"sweep_sharded_{label}", best * 1e6, f"{rate:.0f}_steps_per_s")
    entry["overlap"] = overlap
    entry["overlap_gain"] = (
        overlap["synchronous"]["seconds_per_sweep"]
        / overlap["pipelined"]["seconds_per_sweep"]
    )
    emit("sweep_sharded_overlap", 0.0,
         f"{entry['overlap_gain']:.2f}x_pipelined_over_sync")
    return entry


def run() -> None:
    impls = ["reference", "dense", "sort"]
    if jax.default_backend() == "tpu":
        impls.append("pallas")   # interpret mode off-TPU is not a timing

    results = _bench_scenarios(impls)
    mixed = _bench_mixed()
    recording = _bench_recording()
    sharded = _bench_sharded()

    payload = {
        "bench": "sweep",
        "steps": STEPS,
        "n_slots": N_SLOTS,
        "quick": QUICK,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "platform": platform.platform(),
        "results": results,
        "mixed": mixed,
        "recording": recording,
        "sharded": sharded,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    emit("sweep_json", 0.0, f"wrote_{OUT_PATH}")
