"""Serving engine: greedy generation + continuous batching correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ServeConfig, get_arch
from repro.models import build_model
from repro.serve import ServeEngine, greedy_generate


def _model():
    return build_model(get_arch("qwen1.5-0.5b").reduced(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=64,
    ))


def test_greedy_generate_shapes_and_determinism():
    model = _model()
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (2, 8), 0, 64)
    out1 = greedy_generate(model, params, prompt, steps=6)
    out2 = greedy_generate(model, params, prompt, steps=6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_engine_matches_greedy_reference():
    """Continuous batching must produce the same tokens as plain greedy."""
    model = _model()
    params = model.init(jax.random.key(0))
    prompts = [
        np.asarray([5, 9, 12, 3]),
        np.asarray([40, 2, 61, 17, 8]),
        np.asarray([1, 1, 2]),
    ]
    n_new = 5

    # reference: each prompt alone through greedy_generate (incl. prefill tok)
    refs = []
    for pr in prompts:
        cache = model.init_cache(1, 64)
        logits, cache = jax.jit(model.prefill)(
            params, cache, {"tokens": jnp.asarray(pr[None])}
        )
        toks = [int(jnp.argmax(logits, -1)[0])]
        for t in range(n_new - 1):
            pos = jnp.asarray([pr.shape[0] + t], jnp.int32)
            logits, cache = jax.jit(model.decode)(
                params, cache, jnp.asarray([toks[-1]]), pos
            )
            toks.append(int(jnp.argmax(logits, -1)[0]))
        refs.append(toks)

    # engine: 3 requests through 2 slots (forces recycling)
    eng = ServeEngine(model, params, ServeConfig(max_batch=2, max_seq=64))
    rids = [eng.submit(pr, max_new=n_new) for pr in prompts]
    results = eng.run()
    assert set(results.keys()) == set(rids)
    for rid, ref in zip(rids, refs):
        assert results[rid] == ref, (rid, results[rid], ref)


def test_engine_more_requests_than_slots():
    model = _model()
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, ServeConfig(max_batch=2, max_seq=32))
    rng = np.random.default_rng(0)
    rids = [
        eng.submit(rng.integers(0, 64, size=rng.integers(2, 6)), max_new=3)
        for _ in range(5)
    ]
    results = eng.run()
    assert len(results) == 5
    assert all(len(v) == 3 for v in results.values())
