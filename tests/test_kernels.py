"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode — the kernel body runs in Python on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    flash_attention,
    rglru_linear_scan,
    wkv6,
    idm_accel_kernel,
)
from repro.kernels.ref import (
    ref_attention,
    ref_rglru,
    ref_wkv6,
    ref_idm_accel,
)

TOL = dict(rtol=2e-2, atol=2e-3)
TOL32 = dict(rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- flash attn

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,sk,h,kh,d,causal,window,softcap",
    [
        (1, 128, 128, 2, 2, 64, True, 0, 0.0),      # MHA causal
        (2, 128, 128, 4, 2, 64, True, 0, 0.0),      # GQA
        (1, 256, 256, 2, 1, 128, True, 128, 0.0),   # MQA + sliding window
        (1, 128, 128, 2, 2, 64, True, 0, 50.0),     # gemma2 softcap
        (1, 128, 128, 2, 2, 256, False, 0, 0.0),    # non-causal (encoder)
        (1, 384, 384, 2, 2, 64, True, 0, 0.0),      # multi-tile both axes
    ],
)
def test_flash_attention_matches_ref(b, sq, sk, h, kh, d, causal, window,
                                     softcap, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), dtype)
    k = jax.random.normal(ks[1], (b, sk, kh, d), dtype)
    v = jax.random.normal(ks[2], (b, sk, kh, d), dtype)
    out = flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        interpret=True,
    )
    ref = ref_attention(q, k, v, causal=causal, window=window,
                        softcap=softcap)
    # bf16 outputs are O(1): one ulp at 1.0 is 7.8e-3, so atol below that
    # flags single-element online-softmax rounding differences as failures
    tol = dict(rtol=2e-2, atol=8e-3) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol
    )


def test_flash_attention_small_blocks():
    """Block sizes that force many tiles (exercises the online softmax)."""
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                          interpret=True)
    ref = ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-4
    )


# --------------------------------------------------------------- rg-lru

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,w,bs,bw", [
    (2, 64, 128, 16, 128),
    (1, 128, 256, 128, 128),   # multiple width tiles
    (1, 96, 128, 32, 128),     # multiple seq tiles
])
def test_rglru_matches_ref(b, s, w, bs, bw, dtype):
    ks = jax.random.split(jax.random.key(2), 3)
    a = jax.random.uniform(ks[0], (b, s, w), jnp.float32, 0.7, 0.999)
    x = jax.random.normal(ks[1], (b, s, w), dtype)
    h0 = jax.random.normal(ks[2], (b, w), jnp.float32)
    ys, hf = rglru_linear_scan(a, x, h0, block_s=bs, block_w=bw,
                               interpret=True)
    ys_ref, hf_ref = ref_rglru(a, x, h0)
    tol = TOL if dtype == jnp.bfloat16 else TOL32
    np.testing.assert_allclose(
        np.asarray(ys, np.float32), np.asarray(ys_ref), **tol
    )
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hf_ref),
                               rtol=1e-4, atol=1e-4)


def test_rglru_chunked_equals_whole():
    """State handoff: two chunks of S/2 == one chunk of S."""
    ks = jax.random.split(jax.random.key(3), 3)
    b, s, w = 1, 64, 128
    a = jax.random.uniform(ks[0], (b, s, w), jnp.float32, 0.8, 0.99)
    x = jax.random.normal(ks[1], (b, s, w), jnp.float32)
    h0 = jnp.zeros((b, w), jnp.float32)
    y_all, h_all = rglru_linear_scan(a, x, h0, interpret=True)
    y1, h1 = rglru_linear_scan(a[:, :32], x[:, :32], h0, interpret=True)
    y2, h2 = rglru_linear_scan(a[:, 32:], x[:, 32:], h1, interpret=True)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)), np.asarray(y_all),
        rtol=1e-5, atol=1e-5,
    )
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_all),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- wkv6

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,dk,dv,bs", [
    (1, 32, 2, 16, 16, 16),
    (2, 64, 2, 64, 64, 32),    # full rwkv6 head size, multiple seq tiles
    (1, 48, 1, 32, 16, 16),    # dk != dv
])
def test_wkv6_matches_ref(b, s, h, dk, dv, bs, dtype):
    ks = jax.random.split(jax.random.key(4), 6)
    r = jax.random.normal(ks[0], (b, s, h, dk), dtype)
    k = jax.random.normal(ks[1], (b, s, h, dk), dtype)
    v = jax.random.normal(ks[2], (b, s, h, dv), dtype)
    w = jax.random.uniform(ks[3], (b, s, h, dk), jnp.float32, 0.8, 0.999)
    u = jax.random.normal(ks[4], (h, dk), jnp.float32)
    s0 = jax.random.normal(ks[5], (b, h, dk, dv), jnp.float32)
    y, sf = wkv6(r, k, v, w, u, s0, block_s=bs, interpret=True)
    y_ref, sf_ref = ref_wkv6(r, k, v, w, u, s0)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), **tol
    )
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sf_ref),
                               rtol=1e-3, atol=1e-3)


def test_wkv6_chunked_equals_whole():
    ks = jax.random.split(jax.random.key(5), 6)
    b, s, h, d = 1, 64, 1, 16
    r = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    w = jax.random.uniform(ks[3], (b, s, h, d), jnp.float32, 0.8, 0.999)
    u = jax.random.normal(ks[4], (h, d), jnp.float32)
    s0 = jnp.zeros((b, h, d, d), jnp.float32)
    y_all, s_all = wkv6(r, k, v, w, u, s0, interpret=True)
    y1, s1 = wkv6(r[:, :32], k[:, :32], v[:, :32], w[:, :32], u, s0,
                  interpret=True)
    y2, s2 = wkv6(r[:, 32:], k[:, 32:], v[:, 32:], w[:, 32:], u, s1,
                  interpret=True)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)), np.asarray(y_all),
        rtol=1e-4, atol=1e-4,
    )
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_all),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- idm

@pytest.mark.parametrize("n,block", [(16, 128), (64, 32), (200, 128)])
def test_idm_kernel_matches_ref(n, block):
    ks = jax.random.split(jax.random.key(6), 4)
    pos = jax.random.uniform(ks[0], (n,), jnp.float32, 0.0, 900.0)
    vel = jax.random.uniform(ks[1], (n,), jnp.float32, 5.0, 35.0)
    lane = jax.random.randint(ks[2], (n,), 0, 4)
    active = jax.random.uniform(ks[3], (n,)) < 0.8
    ones = jnp.ones((n,), jnp.float32)
    args = dict(
        v0=30.0 * ones, T=1.5 * ones, a_max=1.4 * ones,
        b_comf=2.0 * ones, s0=2.0 * ones,
    )
    out = idm_accel_kernel(pos, vel, lane, active, block=block,
                           interpret=True, **args)
    ref = ref_idm_accel(pos, vel, lane, active, veh_len=4.5, **args)
    act = np.asarray(active)
    np.testing.assert_allclose(
        np.asarray(out)[act], np.asarray(ref)[act], rtol=1e-5, atol=1e-5
    )


def test_idm_kernel_matches_simulator():
    """The kernel agrees with the live simulator's accel computation."""
    from repro.core import SimConfig, init_state, sample_scenario_params
    from repro.core.simulator import sim_step, neighbor_info, _own_accel

    cfg = SimConfig(n_slots=32)
    sp = sample_scenario_params(jax.random.key(1), cfg)
    st = init_state(cfg, jax.random.key(0))
    step = jax.jit(lambda s: sim_step(s, cfg, sp))
    for _ in range(100):
        st, _ = step(st)
    # reference accel from the simulator's own path (no ramp wall term)
    out = idm_accel_kernel(
        st.pos, st.vel, st.lane, st.active,
        v0=st.v0, T=st.T, a_max=st.a_max, b_comf=st.b_comf, s0=st.s0,
        veh_len=cfg.vehicle_len, interpret=True,
    )
    ref = ref_idm_accel(
        st.pos, st.vel, st.lane, st.active,
        st.v0, st.T, st.a_max, st.b_comf, st.s0, cfg.vehicle_len,
    )
    act = np.asarray(st.active)
    np.testing.assert_allclose(
        np.asarray(out)[act], np.asarray(ref)[act], rtol=1e-5, atol=1e-5
    )
