"""Scenario API tests: merge parity oracle, per-scenario smoke + invariants.

The centerpiece is ``test_highway_merge_parity``: the pre-refactor
``sim_step`` (the seed implementation with the merge hardcoded, plus the
one declared spawn-headway bugfix) is frozen below as ``_legacy_sim_step``,
and the registry-dispatched ``highway_merge`` must reproduce its
trajectories **bit-for-bit** under every neighborhood-engine
implementation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SimConfig,
    get_scenario,
    init_state,
    list_scenarios,
    rollout,
    sample_scenario_params,
    sim_step,
)
from repro.core.neighbors import build_tables, query_lanes
from repro.core.scenario import ScenarioParams, driver_params
from repro.core.simulator import (
    INF,
    SimMetrics,
    SimState,
    _acc,
    idm_accel,
)

ALL_SCENARIOS = list_scenarios()


# ==========================================================================
# the parity oracle: the seed sim_step, frozen, with ONLY the declared
# spawn-headway fix (init speed clamps on the NEW driver's T) applied
# ==========================================================================

def _legacy_own_accel(st, cfg, query_lane, lead_idx, lead_gap, has_lead):
    v_lead = jnp.where(has_lead, st.vel[lead_idx], 0.0)
    gap = jnp.where(has_lead, lead_gap, INF)
    dv = jnp.where(has_lead, st.vel - v_lead, 0.0)
    a = idm_accel(st.vel, dv, gap, st.v0, st.T, st.a_max, st.b_comf, st.s0)
    on_ramp = query_lane == cfg.n_lanes
    wall_gap = cfg.merge_end - st.pos
    a_wall = idm_accel(
        st.vel, st.vel, wall_gap, st.v0, st.T, st.a_max, st.b_comf, st.s0
    )
    a = jnp.where(on_ramp, jnp.minimum(a, a_wall), a)
    return jnp.clip(a, -cfg.b_max, st.a_max)


def _legacy_mobil_candidate(st, cfg, a_now, own, tabs, cand_lane):
    nb = tabs.query(cand_lane)
    li, lg, hl, fi, fg, hf = nb
    a_new = _legacy_own_accel(st, cfg, cand_lane, li, lg, hl)

    a_j_before = jnp.where(hf, a_now[fi], 0.0)
    gap_j_after = jnp.where(hf, fg, INF)
    a_j_after = idm_accel(
        st.vel[fi], st.vel[fi] - st.vel, gap_j_after,
        st.v0[fi], st.T[fi], st.a_max[fi], st.b_comf[fi], st.s0[fi],
    )
    a_j_after = jnp.where(hf, a_j_after, 0.0)

    ki, hk = own.foll_idx, own.has_foll
    lead_pos = jnp.where(own.has_lead, st.pos[own.lead_idx], INF)
    lead_vel = jnp.where(own.has_lead, st.vel[own.lead_idx], 0.0)
    gap_k_after = (
        lead_pos[jnp.arange(st.pos.shape[0])] - st.pos[ki] - cfg.vehicle_len
    )
    a_k_before = jnp.where(hk, a_now[ki], 0.0)
    a_k_after = idm_accel(
        st.vel[ki], st.vel[ki] - lead_vel, gap_k_after,
        st.v0[ki], st.T[ki], st.a_max[ki], st.b_comf[ki], st.s0[ki],
    )
    a_k_after = jnp.where(hk, a_k_after, 0.0)

    incentive = (a_new - a_now) + st.politeness * (
        (a_j_after - a_j_before) + (a_k_after - a_k_before)
    )
    safe = (a_j_after >= -cfg.b_safe) & (
        jnp.where(hf, fg, INF) > 0.0
    ) & (jnp.where(hl, lg, INF) > 0.0)
    return incentive, safe


def _legacy_apply_lane_changes(st, cfg, a_now, own, tabs):
    on_main = (st.lane < cfg.n_lanes) & st.active
    can_change = on_main & (st.cooldown == 0)

    left = jnp.minimum(st.lane + 1, cfg.n_lanes - 1)
    right = jnp.maximum(st.lane - 1, 0)
    inc_l, safe_l = _legacy_mobil_candidate(st, cfg, a_now, own, tabs, left)
    inc_r, safe_r = _legacy_mobil_candidate(st, cfg, a_now, own, tabs, right)
    ok_l = safe_l & (inc_l > cfg.mobil_athr) & (left != st.lane) & can_change
    ok_r = safe_r & (inc_r > cfg.mobil_athr) & (right != st.lane) & can_change

    go_left = ok_l & (~ok_r | (inc_l >= inc_r))
    go_right = ok_r & ~go_left
    new_lane = jnp.where(go_left, left, jnp.where(go_right, right, st.lane))
    changed = go_left | go_right
    cooldown = jnp.where(
        changed, cfg.lane_change_cooldown, jnp.maximum(st.cooldown - 1, 0)
    )
    return new_lane, cooldown, jnp.sum(changed.astype(jnp.int32))


def _legacy_apply_ramp_merges(st, cfg, new_lane, tabs):
    on_ramp = (st.lane == cfg.n_lanes) & st.active
    in_zone = (st.pos >= cfg.merge_start) & (st.pos <= cfg.merge_end)
    zeros = jnp.zeros_like(st.lane)
    _, lg, hl, _, fg, hf = tabs.query(zeros)
    front_need = jnp.where(st.is_cav, 0.7, 1.0) * cfg.merge_gap_front
    rear_need = jnp.where(st.is_cav, 0.7, 1.0) * cfg.merge_gap_rear
    gap_ok = (
        (jnp.where(hl, lg, INF) > front_need)
        & (jnp.where(hf, fg, INF) > rear_need)
    )
    merge = on_ramp & in_zone & gap_ok
    merged_lane = jnp.where(merge, 0, new_lane)
    return merged_lane, jnp.sum(merge.astype(jnp.int32))


def _legacy_spawn(st, cfg, sp, key):
    n = st.pos.shape[0]
    n_spawn_lanes = cfg.n_lanes + 1
    lanes = jnp.arange(n_spawn_lanes)
    ku, kj = jax.random.split(key)
    u = jax.random.uniform(ku, (3, n_spawn_lanes))

    lam = jnp.concatenate([sp.lambda_main, sp.lambda_ramp[None]])
    arrive = u[0] < lam * cfg.dt
    in_lane = st.active[None, :] & (st.lane[None, :] == lanes[:, None])
    nearest = jnp.min(jnp.where(in_lane, st.pos[None, :], INF), axis=1)
    clear = nearest > cfg.spawn_gap

    free = ~st.active
    n_free = jnp.sum(free.astype(jnp.int32))
    want = arrive & clear
    rank = jnp.cumsum(want.astype(jnp.int32)) - want.astype(jnp.int32)
    ok = want & (rank < n_free)
    free_slots = jnp.argsort(~free, stable=True)
    slot = jnp.where(ok, free_slots[jnp.minimum(rank, n - 1)], n)

    cav = u[1] < sp.p_cav
    base_v0 = jnp.where(lanes == cfg.n_lanes, sp.v0_ramp, sp.v0_mean)
    new_v0 = base_v0 * (0.9 + 0.2 * u[2])
    dp = driver_params(cav, kj, n_spawn_lanes)
    # the declared satellite fix: clamp on the NEW driver's T, not the
    # claimed slot's stale previous-occupant T
    init_v = jnp.minimum(new_v0, nearest / jnp.maximum(dp["T"], 0.5))

    def put(arr, val):
        return arr.at[slot].set(val.astype(arr.dtype), mode="drop")

    st = st._replace(
        pos=put(st.pos, jnp.zeros_like(new_v0)),
        vel=put(st.vel, jnp.maximum(init_v * 0.8, 5.0)),
        lane=put(st.lane, lanes),
        active=put(st.active, jnp.ones_like(cav)),
        is_cav=put(st.is_cav, cav),
        v0=put(st.v0, new_v0),
        T=put(st.T, dp["T"]),
        a_max=put(st.a_max, dp["a_max"]),
        b_comf=put(st.b_comf, dp["b_comf"]),
        s0=put(st.s0, dp["s0"]),
        politeness=put(st.politeness, dp["politeness"]),
    )
    return st, jnp.sum(ok.astype(jnp.int32))


def _legacy_sim_step(st, cfg, sp):
    key, k_spawn = jax.random.split(st.key)
    st = st._replace(key=key)
    impl = cfg.neighbor_impl
    n_lanes_total = cfg.n_lanes + 1

    tabs = build_tables(
        st.pos, st.lane, st.active, cfg.vehicle_len, n_lanes_total, impl
    )
    own = tabs.query(st.lane)
    a_now = _legacy_own_accel(st, cfg, st.lane, own.lead_idx, own.lead_gap,
                              own.has_lead)

    new_lane, cooldown, n_lc = _legacy_apply_lane_changes(
        st, cfg, a_now, own, tabs
    )
    new_lane, n_merge = _legacy_apply_ramp_merges(st, cfg, new_lane, tabs)
    st = st._replace(lane=new_lane, cooldown=cooldown)

    nb = query_lanes(
        st.pos, st.lane, st.active, cfg.vehicle_len, st.lane, impl,
        n_lanes_total=n_lanes_total,
    )
    accel = _legacy_own_accel(st, cfg, st.lane, nb.lead_idx, nb.lead_gap,
                              nb.has_lead)
    accel = jnp.where(st.active, accel, 0.0)
    vel = jnp.maximum(st.vel + accel * cfg.dt, 0.0)
    pos = st.pos + vel * cfg.dt
    on_ramp = st.lane == cfg.n_lanes
    pos = jnp.where(on_ramp, jnp.minimum(pos, cfg.merge_end), pos)
    vel = jnp.where(on_ramp & (pos >= cfg.merge_end), 0.0, vel)
    st = st._replace(pos=pos, vel=vel)

    li2, hl2 = nb.lead_idx, nb.has_lead
    lg2 = jnp.where(
        hl2, st.pos[li2] - st.pos - cfg.vehicle_len, INF - cfg.vehicle_len
    )
    crashed = st.active & hl2 & (lg2 < 0.0)
    n_crash = jnp.sum(crashed.astype(jnp.int32))

    exited = st.active & (st.pos > cfg.road_len)
    n_out = jnp.sum(exited.astype(jnp.int32))
    active = st.active & ~exited & ~crashed
    st = st._replace(active=active, pos=jnp.where(active, st.pos, -INF))

    dv = jnp.where(hl2, st.vel - st.vel[li2], 0.0)
    ttc = jnp.where(
        st.active & hl2 & (dv > 0.1), jnp.maximum(lg2, 0.0) / dv, INF
    )
    min_ttc = jnp.min(ttc)

    blocked = (
        st.active & (st.lane == cfg.n_lanes)
        & (st.pos > cfg.merge_end - 10.0) & (st.vel < 0.5)
    )
    n_blocked = jnp.sum(blocked.astype(jnp.int32))

    st, n_spawn = _legacy_spawn(st, cfg, sp, k_spawn)
    st = st._replace(t=st.t + 1)

    delta = SimMetrics(
        throughput=n_out,
        spawned=n_spawn,
        speed_sum=jnp.sum(jnp.where(st.active, st.vel, 0.0)),
        speed_count=jnp.sum(st.active.astype(jnp.float32)),
        collisions=n_crash,
        merges_ok=n_merge,
        ramp_blocked_steps=n_blocked,
        lane_changes=n_lc,
        min_ttc=min_ttc,
        steps=jnp.ones((), jnp.int32),
    )
    return st, delta


def _leaves(tree):
    out = []
    for x in jax.tree.leaves(tree):
        if hasattr(x, "dtype") and jax.dtypes.issubdtype(
            x.dtype, jax.dtypes.prng_key
        ):
            x = jax.random.key_data(x)
        out.append(np.asarray(jax.device_get(x)))
    return out


# ==========================================================================
# parity
# ==========================================================================

@pytest.mark.parametrize(
    "impl,steps",
    [("reference", 250), ("dense", 250), ("sort", 250), ("pallas", 40)],
)
def test_highway_merge_parity(impl, steps):
    """Registry-dispatched highway_merge == the frozen seed step, bitwise."""
    cfg = SimConfig(n_slots=24, scenario="highway_merge", neighbor_impl=impl)
    sp = sample_scenario_params(jax.random.key(1), cfg)
    st_old = init_state(cfg, jax.random.key(0))
    st_new = init_state(cfg, jax.random.key(0))
    m_old, m_new = SimMetrics.zeros(), SimMetrics.zeros()
    step_old = jax.jit(lambda s: _legacy_sim_step(s, cfg, sp))
    step_new = jax.jit(lambda s: sim_step(s, cfg, sp))
    acc = jax.jit(_acc)
    for _ in range(steps):
        st_old, d_old = step_old(st_old)
        st_new, d_new = step_new(st_new)
        m_old, m_new = acc(m_old, d_old), acc(m_new, d_new)
    for a, b in zip(_leaves(st_old), _leaves(st_new)):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(_leaves(m_old), _leaves(m_new)):
        np.testing.assert_array_equal(a, b)


def test_legacy_oracle_exercises_merge():
    """The parity run is meaningful: traffic actually spawns, merges and
    exits in the legacy oracle at the parity horizon."""
    cfg = SimConfig(n_slots=24, scenario="highway_merge")
    sp = sample_scenario_params(jax.random.key(1), cfg)
    st = init_state(cfg, jax.random.key(0))
    m = SimMetrics.zeros()
    step = jax.jit(lambda s: _legacy_sim_step(s, cfg, sp))
    acc = jax.jit(_acc)
    for _ in range(400):
        st, d = step(st)
        m = acc(m, d)
    assert int(m.spawned) > 10
    assert int(m.merges_ok) > 0
    assert int(m.throughput) > 0


# ==========================================================================
# per-scenario smoke + invariants
# ==========================================================================

@pytest.mark.parametrize("name", ALL_SCENARIOS)
def test_scenario_smoke(name):
    cfg = SimConfig(n_slots=16, scenario=name)
    sp = sample_scenario_params(jax.random.key(2), cfg)
    m = rollout(jax.random.key(3), cfg, sp, 300)
    assert int(m.steps) == 300
    assert int(m.spawned) > 0
    assert float(m.speed_sum) > 0.0
    for leaf in jax.tree.leaves(m):
        assert np.all(np.isfinite(np.asarray(leaf)))


@pytest.mark.parametrize("name", ALL_SCENARIOS)
def test_scenario_conservation_and_bounds(name):
    """spawned == exited + crashed + still-active; lanes/positions legal."""
    cfg = SimConfig(n_slots=16, scenario=name)
    geom = get_scenario(name).geometry(cfg)
    sp = sample_scenario_params(jax.random.key(5), cfg)
    st = init_state(cfg, jax.random.key(6))
    m = SimMetrics.zeros()
    step = jax.jit(lambda s: sim_step(s, cfg, sp))
    acc = jax.jit(_acc)
    for _ in range(250):
        st, d = step(st)
        m = acc(m, d)
    active_now = int(np.asarray(st.active).sum())
    assert (
        int(m.spawned)
        == int(m.throughput) + int(m.collisions) + active_now
    )
    act = np.asarray(st.active)
    lane = np.asarray(st.lane)[act]
    pos = np.asarray(st.pos)[act]
    assert np.all((lane >= 0) & (lane < geom.n_lanes_total))
    if geom.ring:
        assert np.all((pos >= 0.0) & (pos <= geom.road_len))
    else:
        assert np.all(pos <= geom.road_len + 1.0)


@pytest.mark.parametrize("name", ALL_SCENARIOS)
def test_scenario_deterministic(name):
    cfg = SimConfig(n_slots=16, scenario=name)
    sp = sample_scenario_params(jax.random.key(7), cfg)
    m1 = rollout(jax.random.key(8), cfg, sp, 150)
    m2 = rollout(jax.random.key(8), cfg, sp, 150)
    for a, b in zip(jax.tree.leaves(m1), jax.tree.leaves(m2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scenarios_actually_differ():
    """Same seeds, different scenarios → different trajectories (the hooks
    are live, not decorative)."""
    outs = []
    for name in ALL_SCENARIOS:
        cfg = SimConfig(n_slots=16, scenario=name)
        sp = sample_scenario_params(jax.random.key(9), cfg)
        outs.append(rollout(jax.random.key(10), cfg, sp, 200))
    sigs = [
        tuple(float(np.asarray(x)) for x in jax.tree.leaves(m))
        for m in outs
    ]
    assert len(set(sigs)) == len(sigs)


@pytest.mark.parametrize("name", ALL_SCENARIOS)
def test_no_spawn_into_occupied_headway(name):
    """No collisions-from-spawn: a blocker parked inside spawn_gap on every
    lane suppresses arrivals entirely."""
    cfg = SimConfig(n_slots=32, scenario=name)
    geom = get_scenario(name).geometry(cfg)
    sp = sample_scenario_params(jax.random.key(11), cfg)
    # demand cranked to 1: every lane wants to spawn every step
    sp = sp._replace(
        lambda_main=jnp.ones_like(sp.lambda_main) * 10.0,
        lambda_ramp=jnp.asarray(10.0),
    )
    st = init_state(cfg, jax.random.key(12))
    n_block = geom.n_lanes_total
    idx = jnp.arange(n_block)
    st = st._replace(
        pos=st.pos.at[idx].set(cfg.spawn_gap * 0.5),
        vel=st.vel.at[idx].set(0.0),
        v0=st.v0.at[idx].set(0.1),       # parked: blockers never move
        lane=st.lane.at[idx].set(idx.astype(st.lane.dtype)),
        active=st.active.at[idx].set(True),
        cooldown=st.cooldown.at[idx].set(10_000),  # and never lane-change
    )
    step = jax.jit(lambda s: sim_step(s, cfg, sp))
    for _ in range(5):
        st, d = step(st)
        assert int(d.spawned) == 0
    assert int(np.asarray(st.active).sum()) == n_block


# ==========================================================================
# the spawn-headway satellite fix: init speed must use the NEW driver's T
# ==========================================================================

def test_spawn_init_speed_uses_fresh_T():
    """A stale, huge T left in a free slot by a previous occupant must not
    throttle the next spawn's entry speed (regression for the st.T[slot]
    read-before-write bug).

    The headway clamp only binds when `nearest` is finite, so park one
    blocker per spawn lane at a moderate distance: with the bug, init_v =
    nearest/stale_T ~ 0 and every spawn enters at the 5 m/s floor; with the
    fix it enters near nearest/T_fresh (>> 5 m/s)."""
    cfg = SimConfig(n_slots=16, scenario="highway_merge")
    sp = sample_scenario_params(jax.random.key(13), cfg)
    sp = sp._replace(
        lambda_main=jnp.ones_like(sp.lambda_main) * 10.0,  # spawn now
        lambda_ramp=jnp.asarray(10.0),
        p_cav=jnp.asarray(0.0),
    )
    st = init_state(cfg, jax.random.key(14))
    n_block = cfg.n_lanes + 1
    idx = jnp.arange(n_block)
    st = st._replace(
        # parked blockers 40 m downstream of the spawn point in every lane
        pos=st.pos.at[idx].set(40.0),
        vel=st.vel.at[idx].set(0.0),
        v0=st.v0.at[idx].set(0.1),
        lane=st.lane.at[idx].set(idx.astype(st.lane.dtype)),
        active=st.active.at[idx].set(True),
        cooldown=st.cooldown.at[idx].set(10_000),
        # stale garbage T everywhere, including the free slots about to be
        # claimed — the buggy read picks this up, the fixed one never sees it
        T=jnp.full_like(st.T, 1e6),
    )
    st, d = jax.jit(lambda s: sim_step(s, cfg, sp))(st)
    assert int(d.spawned) >= 1
    spawned_mask = np.array(st.active)     # writable copy
    spawned_mask[np.asarray(idx)] = False  # drop the blockers
    vel = np.asarray(st.vel)[spawned_mask]
    # fresh human T ~ 1.3-1.7 → init_v ~ 40/T, entry vel = 0.8*init_v > 15;
    # the stale-T bug floors every entry at 5.0 m/s
    assert vel.min() > 10.0
    T = np.asarray(st.T)[spawned_mask]
    assert T.max() < 100.0  # the written T is the freshly drawn one
