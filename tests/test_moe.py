"""MoE dispatch correctness: identical-experts equivalence, capacity, ranks."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch
from repro.models.ffn import ffn_apply, moe_apply, moe_capacity, moe_init


def _cfg(**kw):
    base = get_arch("olmoe-1b-7b").reduced(
        d_model=32, n_experts=4, top_k=2, moe_d_ff=16, d_ff=16,
    )
    return dataclasses.replace(base, **kw) if kw else base


def test_identical_experts_equal_dense_ffn():
    """With every expert identical and ample capacity, MoE == dense FFN
    (gates are normalized to sum 1)."""
    cfg = _cfg(capacity_factor=8.0)
    p = moe_init(jax.random.key(0), cfg)
    # overwrite experts with copies of expert 0
    for name in ("wi_gate", "wi_up", "wo"):
        p[name] = jnp.broadcast_to(p[name][:1], p[name].shape)
    x = jax.random.normal(jax.random.key(1), (2, 8, 32), jnp.float32)
    y, aux = moe_apply(cfg, p, x)
    dense = {"wi_gate": p["wi_gate"][0], "wi_up": p["wi_up"][0],
             "wo": p["wo"][0]}
    ref = ffn_apply(cfg, dense, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_capacity_drops_dont_nan():
    cfg = _cfg(capacity_factor=0.1)  # brutal dropping
    p = moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32), jnp.float32)
    y, aux = moe_apply(cfg, p, x)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(float(aux))
    # with heavy drops, output magnitude is reduced vs ample capacity
    cfg2 = _cfg(capacity_factor=8.0)
    y2, _ = moe_apply(cfg2, p, x)
    assert float(jnp.abs(y).sum()) <= float(jnp.abs(y2).sum()) + 1e-3


def test_capacity_formula():
    cfg = _cfg(capacity_factor=1.25)
    # cap = ceil-ish(1.25 * 2 * 64 / 4), floored at top_k
    assert moe_capacity(cfg, 64) == int(1.25 * 2 * 64 / 4)
    assert moe_capacity(cfg, 1) == cfg.top_k


def test_moe_grads_flow_to_all_parts():
    cfg = _cfg(capacity_factor=4.0)
    p = moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 8, 32), jnp.float32)

    def loss(p):
        y, aux = moe_apply(cfg, p, x)
        return jnp.sum(y * y) + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("router", "wi_gate", "wi_up", "wo"):
        assert float(jnp.abs(g[name]).max()) > 0, name


def test_shared_experts_added():
    cfg = _cfg()
    cfg = dataclasses.replace(cfg, n_shared_experts=1)
    p = moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 4, 32), jnp.float32)
    y, _ = moe_apply(cfg, p, x)
    # zero the routed experts: output reduces to the shared expert alone
    p2 = dict(p)
    for name in ("wi_gate", "wi_up", "wo"):
        p2[name] = jnp.zeros_like(p[name])
    y2, _ = moe_apply(cfg, p2, x)
    ref = ffn_apply(cfg, p["shared"], x)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)
