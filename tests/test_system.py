"""End-to-end system behaviour: the paper's full pipeline in miniature.

sweep (randomized instances, chunked, fault-tolerant) → aggregate dataset →
tokenize → train an assigned-arch LM on it → serve from the trained params.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ServeConfig, TrainConfig, get_arch
from repro.core.aggregate import aggregate_metrics, metrics_to_records
from repro.core.scenario import SimConfig
from repro.core.sweep import SweepConfig, SweepRunner, completion_rate
from repro.data import sim_token_batches
from repro.models import build_model
from repro.serve import ServeEngine
from repro.train.trainer import Trainer


@pytest.fixture(scope="module")
def sweep_state():
    cfg = SweepConfig(
        n_instances=6, steps_per_instance=240, chunk_steps=80,
        sim=SimConfig(n_slots=16), seed=9,
    )
    runner = SweepRunner(cfg)
    return runner.run()


def test_pipeline_sweep_completes(sweep_state):
    assert completion_rate(sweep_state) == 1.0


def test_pipeline_dataset_is_meaningful(sweep_state):
    summary = aggregate_metrics(sweep_state.metrics)
    assert summary["total_spawned"] > 0
    assert summary["total_throughput"] >= 0
    assert 0 < summary["mean_speed"] < 40.0
    recs = metrics_to_records(sweep_state.metrics, sweep_state.params)
    # randomized instances must deviate (the paper's dataset premise). At
    # this short horizon the count metrics saturate (all 16 slots fill, no
    # exits yet), so deviation shows in the continuous measurements.
    speeds = {round(r["mean_speed"], 2) for r in recs}
    pcavs = {round(r["p_cav"], 3) for r in recs}
    assert len(pcavs) == 6  # every instance drew its own scenario
    assert len(speeds) > 1


def test_pipeline_train_then_serve():
    sim = SimConfig(n_slots=16)
    cfg = get_arch("qwen1.5-0.5b").reduced(vocab_size=256)
    model = build_model(cfg)
    tc = TrainConfig(learning_rate=3e-3, warmup_steps=3, total_steps=25,
                     schedule="cosine")
    data = sim_token_batches(cfg, sim, batch=4, seq=32, n_instances=2)
    trainer = Trainer(model, tc, data, log_every=5, log_fn=lambda s: None)
    params, _ = trainer.run(steps=25)
    losses = [h["ce"] for h in trainer.history]
    assert losses[-1] < losses[0]  # learns sim-token structure

    eng = ServeEngine(model, params, ServeConfig(max_batch=2, max_seq=64))
    rid = eng.submit(np.asarray([1, 5, 9]), max_new=4)
    out = eng.run()
    assert len(out[rid]) == 4
    assert all(0 <= t < cfg.vocab_size for t in out[rid])
