"""Output-dataset schema tests: golden record/summary keys + vectorized
records parity.

The Phase-III dataset is consumed downstream (shards, jsonl records, ML
feature code), so its *schema* is a contract: any drift in record keys,
per-scenario alias names or summary keys must fail loudly against the
committed fixture (tests/fixtures/aggregate_schema.json). Regenerate with

    PYTHONPATH=src:tests python tests/test_aggregate.py --regen
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import (
    aggregate_metrics,
    metrics_to_columns,
    metrics_to_records,
)
from repro.core.scenario import ScenarioParams
from repro.core.simulator import SimMetrics

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "aggregate_schema.json")
ROSTER = ("highway_merge", "lane_drop", "stop_and_go", "speed_limit_zone")
N = 8


def _synthetic_dataset():
    """Stacked [N] metrics/params without running a sweep — schema only
    depends on structure, and this keeps the golden test near-instant."""
    rng = np.random.default_rng(0)

    def stack(leaf):
        return jnp.asarray(
            np.arange(N, dtype=np.asarray(leaf).dtype) + np.asarray(leaf)
        )

    metrics = jax.tree.map(stack, SimMetrics.zeros())
    params = ScenarioParams(
        lambda_main=jnp.asarray(rng.random((N, 3), np.float32)),
        lambda_ramp=jnp.asarray(rng.random(N).astype(np.float32)),
        p_cav=jnp.asarray(rng.random(N).astype(np.float32)),
        v0_mean=jnp.asarray(30.0 + rng.random(N).astype(np.float32)),
        v0_ramp=jnp.asarray(rng.random(N).astype(np.float32)),
        seed=jnp.arange(N, dtype=jnp.uint32),
        aux0=jnp.asarray(rng.random(N).astype(np.float32)),
        aux1=jnp.asarray(rng.random(N).astype(np.float32)),
    )
    scenario_ids = np.arange(N) % len(ROSTER)
    return metrics, params, scenario_ids


def _current_schema() -> dict:
    metrics, params, sids = _synthetic_dataset()
    records = metrics_to_records(metrics, params, scenario_ids=sids,
                                 scenario_names=ROSTER)
    summary = aggregate_metrics(metrics, scenario_ids=sids,
                                scenario_names=ROSTER)
    per_scenario_record_keys = {}
    for rec in records:
        per_scenario_record_keys.setdefault(rec["scenario"], list(rec))
    return {
        "record_keys": {k: sorted(v)
                        for k, v in per_scenario_record_keys.items()},
        "summary_keys": sorted(summary),
        "per_scenario_summary_keys": {
            name: sorted(sub) for name, sub in summary["per_scenario"].items()
        },
    }


def test_output_dataset_schema_matches_golden_fixture():
    """Record keys (incl. per-scenario metric_aliases renames) and summary
    keys exactly match the committed fixture — schema drift fails loudly."""
    with open(FIXTURE) as f:
        golden = json.load(f)
    assert _current_schema() == golden, (
        "output-dataset schema drifted from tests/fixtures/"
        "aggregate_schema.json — if intentional, regenerate the fixture "
        "(see module docstring) and call out the schema change in the PR"
    )


def test_records_match_reference_implementation():
    """The vectorized metrics_to_records equals a straightforward
    per-instance reference on values and key ORDER (json round-trip
    stability), not just key sets."""
    metrics, params, sids = _synthetic_dataset()
    records = metrics_to_records(metrics, params, scenario_ids=sids,
                                 scenario_names=ROSTER)
    m = jax.tree.map(lambda x: np.asarray(x), metrics)
    p = jax.tree.map(lambda x: np.asarray(x), params)
    from repro.core.scenarios import get_scenario

    assert len(records) == N
    for i, rec in enumerate(records):
        assert rec["instance"] == i
        assert rec["throughput"] == int(m.throughput[i])
        assert rec["mean_speed"] == float(
            np.float64(m.speed_sum[i]) / max(float(m.speed_count[i]), 1.0)
        )
        assert rec["min_ttc"] == float(np.float64(m.min_ttc[i]))
        assert rec["lambda_main"] == [float(x) for x in p.lambda_main[i]]
        assert rec["p_cav"] == float(np.float64(p.p_cav[i]))
        name = ROSTER[sids[i]]
        assert rec["scenario"] == name
        for generic, alias in get_scenario(name).metric_aliases.items():
            assert rec[alias] == rec[generic]
        assert isinstance(rec["throughput"], int)
        assert isinstance(rec["mean_speed"], float)
    # key order is stable across instances of the same scenario
    for rec in records[len(ROSTER):]:
        ref = next(r for r in records if r["scenario"] == rec["scenario"])
        assert list(rec) == list(ref)


def test_metrics_to_columns_layout():
    metrics, params, sids = _synthetic_dataset()
    cols = metrics_to_columns(metrics, params, scenario_ids=sids,
                              scenario_names=ROSTER)
    for k, v in cols.items():
        assert v.shape[0] == N, k
    assert cols["lambda_main"].shape == (N, 3)
    assert cols["throughput"].dtype == np.int64
    assert cols["scenario"][1] == "lane_drop"
    # scalar param leaves broadcast to per-instance columns
    params2 = params._replace(aux0=jnp.zeros(()))
    cols2 = metrics_to_columns(metrics, params2)
    assert cols2["aux0"].shape == (N,)


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        os.makedirs(os.path.dirname(FIXTURE), exist_ok=True)
        with open(FIXTURE, "w") as f:
            json.dump(_current_schema(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"regenerated {FIXTURE}")
