"""Unit + property tests for the IDM/MOBIL highway-merge simulator."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core import (
    SimConfig,
    sample_scenario_params,
    init_state,
    sim_step,
    rollout,
)
from repro.core.simulator import idm_accel, neighbor_info, SimMetrics
from repro.core.scenario import ScenarioParams

CFG = SimConfig(n_slots=16)


def _params(key=1):
    return sample_scenario_params(jax.random.key(key), CFG)


# ---------------------------------------------------------------- IDM unit

def test_idm_free_road_accelerates():
    a = idm_accel(
        v=jnp.float32(10.0), dv=jnp.float32(0.0), gap=jnp.float32(1e9),
        v0=jnp.float32(30.0), T=jnp.float32(1.5), a_max=jnp.float32(1.4),
        b_comf=jnp.float32(2.0), s0=jnp.float32(2.0),
    )
    assert float(a) > 1.0  # nearly a_max when far below v0 with no lead


def test_idm_at_desired_speed_no_accel():
    a = idm_accel(
        v=jnp.float32(30.0), dv=jnp.float32(0.0), gap=jnp.float32(1e9),
        v0=jnp.float32(30.0), T=jnp.float32(1.5), a_max=jnp.float32(1.4),
        b_comf=jnp.float32(2.0), s0=jnp.float32(2.0),
    )
    assert abs(float(a)) < 1e-3


def test_idm_close_gap_brakes():
    a = idm_accel(
        v=jnp.float32(30.0), dv=jnp.float32(10.0), gap=jnp.float32(5.0),
        v0=jnp.float32(30.0), T=jnp.float32(1.5), a_max=jnp.float32(1.4),
        b_comf=jnp.float32(2.0), s0=jnp.float32(2.0),
    )
    assert float(a) < -4.0


@settings(max_examples=25, deadline=None)
@given(
    v=st.floats(0.0, 40.0),
    gap=st.floats(0.5, 500.0),
    dv=st.floats(-10.0, 10.0),
)
def test_idm_bounded_above_by_amax(v, gap, dv):
    a = idm_accel(
        jnp.float32(v), jnp.float32(dv), jnp.float32(gap),
        jnp.float32(30.0), jnp.float32(1.5), jnp.float32(1.4),
        jnp.float32(2.0), jnp.float32(2.0),
    )
    assert float(a) <= 1.4 + 1e-5


# ------------------------------------------------------------- neighbors

def test_neighbor_info_basic():
    pos = jnp.array([0.0, 50.0, 100.0, 30.0], jnp.float32)
    lane = jnp.array([0, 0, 0, 1], jnp.int32)
    active = jnp.ones(4, bool)
    li, lg, hl, fi, fg, hf = neighbor_info(pos, lane, active, 4.5, lane)
    # vehicle 0's lead is 1 (gap 45.5); vehicle 1's lead is 2
    assert int(li[0]) == 1 and abs(float(lg[0]) - 45.5) < 1e-4
    assert int(li[1]) == 2
    assert not bool(hl[2])  # front of lane 0
    assert not bool(hl[3])  # alone in lane 1
    assert bool(hf[1]) and int(fi[1]) == 0


def test_neighbor_ignores_inactive():
    pos = jnp.array([0.0, 50.0], jnp.float32)
    lane = jnp.array([0, 0], jnp.int32)
    active = jnp.array([True, False])
    _, _, hl, _, _, _ = neighbor_info(pos, lane, active, 4.5, lane)
    assert not bool(hl[0])


# ------------------------------------------------------------- step/rollout

def test_step_preserves_shapes_and_finiteness():
    st0 = init_state(CFG, jax.random.key(0))
    sp = _params()
    st1, d = jax.jit(lambda s: sim_step(s, CFG, sp))(st0)
    for a, b in zip(jax.tree.leaves(st0), jax.tree.leaves(st1)):
        assert a.shape == b.shape and a.dtype == b.dtype
    for leaf in jax.tree.leaves(d):
        assert np.all(np.isfinite(np.asarray(leaf)))


def test_rollout_spawns_and_moves_traffic():
    sp = _params()
    m = rollout(jax.random.key(0), CFG, sp, 400)
    assert int(m.spawned) > 0
    assert float(m.speed_sum) > 0
    assert int(m.steps) == 400


def test_rollout_deterministic():
    sp = _params()
    m1 = rollout(jax.random.key(7), CFG, sp, 200)
    m2 = rollout(jax.random.key(7), CFG, sp, 200)
    for a, b in zip(jax.tree.leaves(m1), jax.tree.leaves(m2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rollout_seed_sensitivity():
    sp = _params()
    m1 = rollout(jax.random.key(1), CFG, sp, 400)
    m2 = rollout(jax.random.key(2), CFG, sp, 400)
    diff = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(m1), jax.tree.leaves(m2))
    )
    assert diff  # randomized instances must deviate (the paper's premise)


def test_speeds_stay_physical():
    """No vehicle exceeds ~max desired speed; none go backwards."""
    sp = _params()
    st = init_state(CFG, jax.random.key(3))
    step = jax.jit(lambda s: sim_step(s, CFG, sp))
    for _ in range(300):
        st, _ = step(st)
    vel = np.asarray(st.vel)[np.asarray(st.active)]
    if vel.size:
        assert vel.min() >= 0.0
        assert vel.max() <= 40.0


def test_vehicles_stay_on_road():
    sp = _params()
    st = init_state(CFG, jax.random.key(4))
    step = jax.jit(lambda s: sim_step(s, CFG, sp))
    for _ in range(300):
        st, _ = step(st)
    act = np.asarray(st.active)
    lane = np.asarray(st.lane)[act]
    pos = np.asarray(st.pos)[act]
    assert np.all((lane >= 0) & (lane <= CFG.n_lanes))
    assert np.all(pos <= CFG.road_len + 1.0)
    # ramp vehicles never pass the ramp end
    on_ramp = lane == CFG.n_lanes
    assert np.all(pos[on_ramp] <= CFG.merge_end + 1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_property_conservation_of_vehicles(seed):
    """spawned == exited + crashed + still-active (no vehicle lost)."""
    sp = _params()
    st = init_state(CFG, jax.random.key(seed))
    m = SimMetrics.zeros()
    step = jax.jit(lambda s: sim_step(s, CFG, sp))
    from repro.core.simulator import _acc

    for _ in range(150):
        st, d = step(st)
        m = jax.jit(_acc)(m, d)
    active_now = int(np.asarray(st.active).sum())
    assert int(m.spawned) == int(m.throughput) + int(m.collisions) + active_now
