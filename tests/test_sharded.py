"""Device-sharded, pipelined sweep executor (repro.core.sweep BlockPlan path).

The paper distributes a batch of simulations "across an arbitrary number
of computing nodes"; our executor shards the instance axis over a device
mesh with LPT-packed per-device blocks and overlaps host I/O with device
compute. The standing bar, tested here:

- **bit-for-bit parity**: N-device sharded and pipelined runs reproduce
  the 1-device synchronous trajectories, shards and metrics exactly,
  including injected failures and checkpoint kill/resume — and a
  checkpoint taken on N devices resumes on M devices;
- **planner invariants** (hypothesis): exactly-once scheduling, done-pool
  padding, per-device blocks sized in ``workers_per_device`` multiples,
  and LPT never splitting a scenario group across devices when it fits
  its fair share;
- **workers × devices composition**: ``--workers`` means instances per
  device, so the worker grid (fault injection, padding granularity) is
  ``devices × workers`` — the regression the single-device-era injector
  derivation used to get wrong.

Runs on simulated CPU devices (the module forces
``--xla_force_host_platform_device_count=8`` before jax initializes, the
same mechanism as the launcher's ``--devices``).
"""

import os
import sys

if "jax" not in sys.modules or "--xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    ).strip()

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from conftest import assert_states_equal
from repro.ckpt import CheckpointManager
from repro.core import SimConfig
from repro.core.fault import FailureInjector, run_with_failures
from repro.core.record import RecordConfig
from repro.core.sweep import (
    BlockPlan,
    SweepConfig,
    SweepRunner,
    completion_rate,
    plan_chunk_blocks,
)

needs_devices = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 (simulated) devices; run with "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

SIM = SimConfig(n_slots=16)
MIX2 = ("highway_merge", "lane_drop")
REC = RecordConfig(record_every=10, k_slots=4)


def _cfg(**kw):
    base = dict(
        n_instances=10,
        steps_per_instance=80,
        chunk_steps=40,
        sim=SIM,
        seed=3,
        scenario_mix=MIX2,
        record=REC,
        vary_horizon=True,
        min_horizon_frac=0.3,
    )
    base.update(kw)
    return SweepConfig(**base)


def _mesh(d):
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()[:d]), ("workers",))


_REF: dict = {}  # the 1-device synchronous reference, computed once


def _ref_state():
    if "state" not in _REF:
        _REF["state"] = SweepRunner(_cfg()).run()
    return _REF["state"]


# --------------------------------------------------------------------------
# sharded-vs-single-device parity
# --------------------------------------------------------------------------


@needs_devices
@pytest.mark.parametrize("dispatch,wpd", [
    ("grouped", 1), ("grouped", 2), ("switch", 1), ("auto", 2),
])
def test_sharded_matches_single_device_bitwise(dispatch, wpd):
    """A 4-device run — trace buffer included — is bit-for-bit equal to the
    1-device reference: the LPT block packing is just another physical-row
    permutation confined to the inside of run_chunk."""
    runner = SweepRunner(_cfg(dispatch=dispatch), mesh=_mesh(4),
                         workers_per_device=wpd)
    got = runner.run()
    assert completion_rate(got) == 1.0
    assert_states_equal(_ref_state(), got)


@needs_devices
def test_sharded_device_count_invariance():
    """2-, 3- and 4-device runs all agree (3 does not divide 10 — the
    resting state stays unsharded and only the gathered blocks shard)."""
    ref = _ref_state()
    for d in (2, 3):
        got = SweepRunner(_cfg(), mesh=_mesh(d)).run()
        assert_states_equal(ref, got)


@needs_devices
def test_sharded_failure_parity():
    """The same injection plan kills the same logical instances on a mesh:
    failure masks are worker-grid-based, never block-placement-based."""
    plan = {0: [0], 1: [2, 3]}
    clean = _ref_state()
    finals = {}
    for label, mesh, wpd in (("1dev", None, 4), ("4dev", _mesh(4), 1)):
        runner = SweepRunner(_cfg(), mesh=mesh, workers_per_device=wpd)
        injector = FailureInjector(n_workers=4, plan=dict(plan))
        finals[label], info = run_with_failures(runner, injector)
        assert info["completion_rate"] == 1.0
        assert len(info["failure_events"]) == 2
        assert_states_equal(clean, finals[label]._replace(chunk=clean.chunk))


@needs_devices
def test_resume_across_device_count_change(tmp_path):
    """A checkpoint taken on a 4-device mesh resumes on 1 device (and the
    other way round) bit-for-bit — sharding never leaks into the state."""
    cfg = _cfg()
    clean = _ref_state()
    ckpt = CheckpointManager(str(tmp_path / "sw"), async_write=False)

    runner4 = SweepRunner(cfg, mesh=_mesh(4))
    state = runner4.init()
    state = runner4.run_chunk(state)
    ckpt.save(int(jax.device_get(state.chunk)), state)

    # resume the 4-device checkpoint on a single device
    final, info = run_with_failures(
        SweepRunner(cfg), FailureInjector(n_workers=4, plan={}), ckpt=ckpt
    )
    assert info["completion_rate"] == 1.0
    assert_states_equal(clean, final)

    # and a 1-device checkpoint on a 4-device mesh
    ckpt2 = CheckpointManager(str(tmp_path / "sw2"), async_write=False)
    runner1 = SweepRunner(cfg)
    state = runner1.init()
    state = runner1.run_chunk(state)
    ckpt2.save(int(jax.device_get(state.chunk)), state)
    final2, info2 = run_with_failures(
        SweepRunner(cfg, mesh=_mesh(4)),
        FailureInjector(n_workers=4, plan={}), ckpt=ckpt2,
    )
    assert info2["completion_rate"] == 1.0
    assert_states_equal(clean, final2)


@needs_devices
def test_elastic_remesh_mid_sweep():
    """remesh() moves a live sweep between device counts mid-run."""
    runner = SweepRunner(_cfg(), mesh=_mesh(4))
    state = runner.init()
    state = runner.run_chunk(state)
    state = runner.remesh(state, _mesh(2))
    final = runner.run(state)
    assert completion_rate(final) == 1.0
    assert_states_equal(_ref_state(), final)


# --------------------------------------------------------------------------
# pipelined-vs-synchronous parity (state, shards, manifest, checkpoints)
# --------------------------------------------------------------------------


def _run_to_dataset(tmp_path, name, *, pipeline, mesh=None, plan=None):
    from repro.data.shards import DatasetWriter, ShardedDataset

    cfg = _cfg()
    root = str(tmp_path / name)
    runner = SweepRunner(cfg, mesh=mesh)
    writer = DatasetWriter(root, cfg, shard_size=4)
    ckpt = CheckpointManager(str(tmp_path / (name + "_ck")),
                            async_write=False)
    injector = FailureInjector(n_workers=4, plan=dict(plan or {}))
    state, info = run_with_failures(runner, injector, ckpt=ckpt,
                                    writer=writer, pipeline=pipeline)
    writer.finalize(summary=None, fault_info=info)
    return state, info, ShardedDataset.load(root)


@needs_devices
@pytest.mark.parametrize("plan", [{}, {0: [1], 1: [0, 2]}])
def test_pipelined_matches_synchronous_dataset(tmp_path, plan):
    """Pipelining reorders WHEN files are written, never what: final state,
    shard npz arrays, jsonl records and manifest shard index are identical
    to the synchronous loop — with and without injected failures."""
    s_sync, i_sync, ds_sync = _run_to_dataset(
        tmp_path, "sync", pipeline=False, plan=plan)
    s_pipe, i_pipe, ds_pipe = _run_to_dataset(
        tmp_path, "pipe", pipeline=True, mesh=_mesh(4), plan=plan)
    assert i_sync["completion_rate"] == i_pipe["completion_rate"] == 1.0
    assert_states_equal(s_sync, s_pipe)
    assert ds_sync.manifest["shards"] == ds_pipe.manifest["shards"]
    for a, b in zip(ds_sync.iter_shards(), ds_pipe.iter_shards()):
        assert sorted(a) == sorted(b)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])
    assert ds_sync.records() == ds_pipe.records()


def test_overlapping_begin_drain_never_duplicates(tmp_path):
    """Two outstanding begin_drain handles must not drain an instance
    twice: ids are reserved at begin time, so a deeper look-ahead than the
    run loop's 1-chunk pipeline still upholds no-duplicate-rows."""
    from repro.data.shards import DatasetWriter, ShardedDataset

    cfg = SweepConfig(n_instances=4, steps_per_instance=40, chunk_steps=40,
                      sim=SIM, seed=0, record=REC)
    state = SweepRunner(cfg).run()
    w = DatasetWriter(str(tmp_path / "ds"), cfg, shard_size=2)
    h1 = w.begin_drain(state)
    h2 = w.begin_drain(state)  # overlapping: everything is already in flight
    assert h2 is None
    assert w.finish_drain(h1) == 4
    assert w.finish_drain(h2) == 0
    assert w.begin_drain(state) is None  # and persisted ids stay excluded
    w.finalize()
    ds = ShardedDataset.load(str(tmp_path / "ds"))
    assert ds.n_instances == 4
    assert sorted(r["instance"] for r in ds.records()) == [0, 1, 2, 3]


def test_pipelined_checkpoint_kill_resume(tmp_path):
    """A kill mid-pipelined-run (checkpoint lagging one chunk behind) still
    resumes to a bit-identical final state — pipeline lag is within what
    resume already tolerates. Runs on 1 device so it also covers the
    pipelined loop without a mesh."""
    cfg = _cfg()
    ckpt = CheckpointManager(str(tmp_path / "sw"), async_write=False)
    runner = SweepRunner(cfg)
    state = runner.init()
    # two pipelined "iterations" by hand: run_with_failures with max_chunks
    _, info = run_with_failures(runner, FailureInjector(4, {}), ckpt=ckpt,
                                pipeline=True, max_chunks=2)
    # the deferred-flush guarantees the LAST completed chunk is persisted
    assert ckpt.has_checkpoint()
    final, info = run_with_failures(
        SweepRunner(cfg), FailureInjector(4, {}), ckpt=ckpt, pipeline=True
    )
    assert info["completion_rate"] == 1.0
    assert_states_equal(_ref_state(), final)


# --------------------------------------------------------------------------
# workers x devices composition (regression: injector assumed 1 device)
# --------------------------------------------------------------------------


@needs_devices
def test_workers_compose_with_devices():
    """--workers is instances PER DEVICE: the worker grid the injector and
    the planner see is devices x workers, and per-device blocks are padded
    to a workers multiple."""
    runner = SweepRunner(_cfg(), mesh=_mesh(4), workers_per_device=2)
    assert runner._n_workers() == 8
    bp = runner.plan_chunk_sharded(runner.init())
    assert bp.cap % 2 == 0
    assert bp.take.size == 4 * bp.cap

    # a (4 devices x 2 workers) grid and a (1 device x 8 workers) grid see
    # the SAME logical worker->instance failure map, so injected runs agree
    plan = {0: [5], 1: [1, 6]}
    finals = []
    for mesh, wpd in ((_mesh(4), 2), (None, 8)):
        r = SweepRunner(_cfg(), mesh=mesh, workers_per_device=wpd)
        injector = FailureInjector(n_workers=r._n_workers(), plan=dict(plan))
        st, info = run_with_failures(r, injector)
        assert info["completion_rate"] == 1.0
        finals.append(st)
    assert_states_equal(finals[0], finals[1])

    with pytest.raises(ValueError):
        SweepRunner(_cfg(), workers_per_device=0)


def test_make_host_mesh_rejects_oversubscription():
    from repro.launch.mesh import make_host_mesh

    with pytest.raises(ValueError):
        make_host_mesh(max_workers=jax.device_count() + 1)
    mesh = make_host_mesh(max_workers=1)
    assert mesh.devices.size == 1


def test_force_host_device_count_rewrites_flag(monkeypatch):
    from repro.launch.mesh import force_host_device_count

    monkeypatch.setenv(
        "XLA_FLAGS",
        "--foo=1 --xla_force_host_platform_device_count=2",
    )
    force_host_device_count(16)
    assert os.environ["XLA_FLAGS"] == (
        "--foo=1 --xla_force_host_platform_device_count=16"
    )
    with pytest.raises(ValueError):
        force_host_device_count(0)


# --------------------------------------------------------------------------
# plan_chunk_blocks invariants (hypothesis)
# --------------------------------------------------------------------------


def _check_block_invariants(done, sids, n_devices, wpd, grouped, compaction,
                            n_scenarios):
    bp = plan_chunk_blocks(done, sids, n_devices, wpd,
                           grouped=grouped, compaction=compaction)
    n = done.size
    pending = np.flatnonzero(~done)
    expected = pending if compaction else np.arange(n)
    if expected.size == 0:
        assert bp is None
        return None
    assert isinstance(bp, BlockPlan)
    D, cap = n_devices, bp.cap
    assert cap % wpd == 0 and cap >= 1
    assert bp.take.size == D * cap and bp.keep.size == D * cap
    assert bp.block_sid.size == D
    # every live instance's result is kept EXACTLY once
    kept = bp.take[bp.keep]
    assert sorted(kept.tolist()) == sorted(expected.tolist())
    done_pool = np.flatnonzero(done)
    pad = bp.take[~bp.keep]
    if done_pool.size:
        assert done[pad].all()  # padding only from finished instances
    else:
        assert set(pad.tolist()) <= set(expected.tolist())
    # per-device blocks: uniform blocks are single-scenario on kept rows
    fair = -(-expected.size // D)
    device_of = {}
    for d in range(D):
        rows = slice(d * cap, (d + 1) * cap)
        k_ids = bp.take[rows][bp.keep[rows]]
        for i in k_ids:
            device_of[int(i)] = d
        if k_ids.size and grouped:
            block_scen = set(sids[k_ids].tolist())
            if bp.block_sid[d] >= 0:
                assert block_scen == {int(bp.block_sid[d])}
            else:
                assert len(block_scen) > 1  # -1 only when genuinely mixed
        elif k_ids.size:
            assert bp.block_sid[d] == -1  # switch program
    # LPT never splits a group that fits its fair share
    if grouped:
        for s in np.unique(sids[expected]):
            members = [int(i) for i in expected if sids[i] == s]
            if len(members) <= fair:
                assert len({device_of[i] for i in members}) == 1, (
                    f"scenario {s} fits ({len(members)} <= {fair}) but was "
                    f"split across devices"
                )
    return bp


@settings(max_examples=80, deadline=None)
@given(
    n=st.integers(1, 40),
    n_devices=st.integers(1, 8),
    wpd=st.integers(1, 4),
    n_scenarios=st.integers(1, 5),
    grouped=st.booleans(),
    compaction=st.booleans(),
    seed=st.integers(0, 2**32 - 1),
)
def test_property_block_plan_invariants(n, n_devices, wpd, n_scenarios,
                                        grouped, compaction, seed):
    """Exactly-once scheduling, done-pool padding, wpd-multiple caps,
    uniform-block scenario purity, and the LPT no-split guarantee."""
    rng = np.random.default_rng(seed)
    done = rng.random(n) < rng.uniform(0.0, 1.0)
    sids = rng.integers(0, n_scenarios, size=n)
    _check_block_invariants(done, sids, n_devices, wpd, grouped, compaction,
                            n_scenarios)


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(1, 40),
    n_devices=st.integers(1, 8),
    n_scenarios=st.integers(1, 5),
    seed=st.integers(0, 2**32 - 1),
)
def test_property_block_scatter_roundtrip(n, n_devices, n_scenarios, seed):
    """Gather -> per-block transform -> keep-masked scatter touches every
    live slot exactly once and no other slot (sharding-agnostic recording
    rests on this)."""
    rng = np.random.default_rng(seed)
    done = rng.random(n) < rng.uniform(0.0, 1.0)
    sids = rng.integers(0, n_scenarios, size=n)
    bp = plan_chunk_blocks(done, sids, n_devices, 1,
                           grouped=True, compaction=True)
    base = rng.normal(size=n)
    out = base.copy()
    if bp is not None:
        part = out[bp.take] + 1.0
        out[bp.take[bp.keep]] = part[bp.keep]
    np.testing.assert_allclose(out[~done], base[~done] + 1.0)
    np.testing.assert_array_equal(out[done], base[done])


def test_block_plan_invariants_seedwise():
    """The same invariants exercised without hypothesis (which CI installs
    but minimal environments may not): 200 seeded random bitmaps across
    the device/worker/scenario grid."""
    rng = np.random.default_rng(0)
    for _ in range(200):
        n = int(rng.integers(1, 40))
        n_devices = int(rng.integers(1, 9))
        wpd = int(rng.integers(1, 4))
        n_scenarios = int(rng.integers(1, 6))
        done = rng.random(n) < rng.uniform(0.0, 1.0)
        sids = rng.integers(0, n_scenarios, size=n)
        _check_block_invariants(done, sids, n_devices, wpd,
                                bool(rng.integers(2)), bool(rng.integers(2)),
                                n_scenarios)


def test_block_plan_lpt_balance_example():
    """Deterministic example: 3 groups of sizes 6/3/3 on 2 devices, fair
    share 6 -> the big group occupies one device whole, the two small
    groups share the other, both blocks uniform."""
    done = np.zeros(12, bool)
    sids = np.array([0] * 6 + [1] * 3 + [2] * 3)
    bp = plan_chunk_blocks(done, sids, 2, 1, grouped=True, compaction=True)
    assert bp.cap == 6 and bp.keep.all()
    blocks = [bp.take[:6], bp.take[6:]]
    scen = [set(sids[b].tolist()) for b in blocks]
    assert {0} in scen
    assert {1, 2} in scen
    # the shared block is mixed (two scenarios) -> -1; the solo one uniform
    assert sorted(bp.block_sid.tolist()) == [-1, 0]


def test_block_plan_switch_mode_marks_all_mixed():
    bp = plan_chunk_blocks(np.zeros(8, bool), np.arange(8) % 2, 4, 1,
                           grouped=False, compaction=False)
    assert (bp.block_sid == -1).all()
    assert bp.keep.all()


def test_block_plan_empty():
    assert plan_chunk_blocks(np.ones(4, bool), np.zeros(4, np.int64), 4, 1,
                             grouped=True, compaction=True) is None
