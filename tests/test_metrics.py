"""Paper §5 accounting: Table 5.1 timelines, evenness, LPT scheduling."""

import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core.metrics import (
    PAPER_CLUSTER,
    PAPER_PC,
    PAPER_TIMESTAMPS,
    ClusterSpec,
    block_assignment,
    cluster_timeline,
    distribution_evenness,
    lpt_assignment,
    makespan,
    personal_timeline,
    speedup_at,
)


def test_cluster_timeline_matches_paper_table_5_1():
    spec = ClusterSpec()
    assert cluster_timeline(spec, PAPER_TIMESTAMPS) == PAPER_CLUSTER


def test_personal_timeline_tracks_paper_table_5_1():
    # the paper's PC numbers are an empirical (slightly non-uniform) rate;
    # the constant-rate model must track within ±2 runs and hit 74 at 12 h
    ours = personal_timeline(720 / 74, PAPER_TIMESTAMPS)
    assert all(abs(a - b) <= 3 for a, b in zip(ours, PAPER_PC))
    assert ours[-1] == 74


def test_paper_speedup_is_31x():
    s = speedup_at(ClusterSpec(), 720 / 74, 720.0)
    assert abs(s - 2304 / 74) < 1e-9
    assert 31.0 <= s <= 31.2


def test_scaling_projection_doubles_with_nodes():
    """Paper §5.1: 12 nodes should give ~62x (4,608 runs)."""
    spec12 = ClusterSpec(n_nodes=12)
    assert cluster_timeline(spec12, [720])[0] == 4608


def test_block_assignment_even():
    ev = distribution_evenness(block_assignment(48, 6), 6)
    assert ev["perfectly_even"] and ev["counts"] == [8] * 6


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 200),
    w=st.integers(1, 16),
)
def test_property_block_assignment_covers_all(n, w):
    a = block_assignment(n, w)
    assert a.shape == (n,)
    assert (a >= 0).all() and (a < w).all()
    ev = distribution_evenness(a, w)
    # block assignment is near-even: max-min <= ceil block size
    assert ev["max"] - ev["min"] <= -(-n // w)


@settings(max_examples=30, deadline=None)
@given(
    costs=st.lists(st.floats(0.1, 10.0), min_size=4, max_size=64),
    w=st.integers(2, 8),
)
def test_property_lpt_within_classical_bound(costs, w):
    """LPT is a (4/3 − 1/3w)-approximation — assert the classical bound.

    (LPT can lose to block assignment on adversarial ties, so 'never worse
    than block' is NOT a theorem; the bound below is.)"""
    costs = np.asarray(costs)
    m_lpt = makespan(costs, lpt_assignment(costs, w), w)
    # greedy list-scheduling guarantee: makespan ≤ avg + (1 − 1/w)·max
    assert m_lpt <= costs.sum() / w + (1 - 1 / w) * costs.max() + 1e-6


def test_lpt_beats_block_on_typical_variable_horizons():
    rng = np.random.default_rng(0)
    wins = 0
    for trial in range(20):
        costs = rng.uniform(0.3, 1.0, size=48)
        m_block = makespan(costs, block_assignment(48, 6), 6)
        m_lpt = makespan(costs, lpt_assignment(costs, 6), 6)
        wins += m_lpt <= m_block + 1e-9
    assert wins >= 18  # overwhelmingly better on realistic cost draws


def test_lpt_respects_lower_bound():
    costs = np.asarray([5.0, 3.0, 3.0, 2.0, 2.0, 2.0, 1.0])
    m = makespan(costs, lpt_assignment(costs, 3), 3)
    assert m >= costs.sum() / 3 - 1e-9   # can't beat the average
    assert m >= costs.max() - 1e-9       # can't beat the largest job
    assert m <= costs.sum() / 3 * (4 / 3 - 1 / 9) + costs.max()  # LPT bound


# --------------------------------------------------------------------------
# paper-table regression: the committed constants ARE Table 5.1, and the
# models must keep reproducing them exactly
# --------------------------------------------------------------------------


def test_paper_constants_are_table_5_1_verbatim():
    """The fixture constants must stay the paper's Table 5.1 numbers — any
    edit to them is a regression of the reproduction target itself."""
    assert PAPER_TIMESTAMPS == [30, 60, 90, 120, 240, 360, 720]
    assert PAPER_PC == [4, 7, 11, 15, 26, 40, 74]
    assert PAPER_CLUSTER == [96, 192, 288, 384, 768, 1152, 2304]
    # internal consistency: cluster column is 48 runs per 15-min slice
    assert all(c == (t // 15) * 48
               for t, c in zip(PAPER_TIMESTAMPS, PAPER_CLUSTER))


def test_speedup_trajectory_tracks_table_5_1():
    """Cluster-over-PC speedup at every Table 5.1 timestamp, ending at the
    paper's headline ~31x at 12 h."""
    spec = ClusterSpec()
    rate = 720 / 74
    for t, pc, cluster in zip(PAPER_TIMESTAMPS, PAPER_PC, PAPER_CLUSTER):
        s = speedup_at(spec, rate, float(t))
        # the constant-rate PC model tracks the empirical column within
        # ±3 runs (see test_personal_timeline_tracks_paper_table_5_1), so
        # the speedup must sit inside the ratio band that slack implies
        assert cluster / (pc + 3) - 1e-9 <= s <= cluster / max(pc - 3, 1) + 1e-9
    assert abs(speedup_at(spec, rate, 720.0) - 2304 / 74) < 1e-9


def test_lpt_never_loses_to_block_on_randomized_variable_costs():
    """On the paper's 48-instance / 6-node shape with realistic variable
    costs (the vary_horizon straggler population), LPT's makespan is never
    worse than PBS-style block assignment — checked per-trial on 200
    deterministic draws, not just in aggregate."""
    for seed in range(200):
        rng = np.random.default_rng(seed)
        costs = rng.uniform(0.3, 1.0, size=48)
        m_block = makespan(costs, block_assignment(48, 6), 6)
        m_lpt = makespan(costs, lpt_assignment(costs, 6), 6)
        assert m_lpt <= m_block + 1e-9, (seed, m_lpt, m_block)


@settings(max_examples=40, deadline=None)
@given(
    costs=st.lists(st.floats(0.05, 5.0), min_size=2, max_size=96),
    w=st.integers(1, 12),
)
def test_property_lpt_respects_lower_bounds(costs, w):
    """Any assignment's makespan is bounded below by max(avg load, max
    cost); LPT must sit between that bound and its classical guarantee."""
    costs = np.asarray(costs)
    m = makespan(costs, lpt_assignment(costs, w), w)
    lower = max(costs.sum() / w, costs.max())
    assert m >= lower - 1e-9
    assert m <= costs.sum() / w + (1 - 1 / w) * costs.max() + 1e-6
