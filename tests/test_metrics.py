"""Paper §5 accounting: Table 5.1 timelines, evenness, LPT scheduling."""

import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core.metrics import (
    PAPER_CLUSTER,
    PAPER_PC,
    PAPER_TIMESTAMPS,
    ClusterSpec,
    block_assignment,
    cluster_timeline,
    distribution_evenness,
    lpt_assignment,
    makespan,
    personal_timeline,
    speedup_at,
)


def test_cluster_timeline_matches_paper_table_5_1():
    spec = ClusterSpec()
    assert cluster_timeline(spec, PAPER_TIMESTAMPS) == PAPER_CLUSTER


def test_personal_timeline_tracks_paper_table_5_1():
    # the paper's PC numbers are an empirical (slightly non-uniform) rate;
    # the constant-rate model must track within ±2 runs and hit 74 at 12 h
    ours = personal_timeline(720 / 74, PAPER_TIMESTAMPS)
    assert all(abs(a - b) <= 3 for a, b in zip(ours, PAPER_PC))
    assert ours[-1] == 74


def test_paper_speedup_is_31x():
    s = speedup_at(ClusterSpec(), 720 / 74, 720.0)
    assert abs(s - 2304 / 74) < 1e-9
    assert 31.0 <= s <= 31.2


def test_scaling_projection_doubles_with_nodes():
    """Paper §5.1: 12 nodes should give ~62x (4,608 runs)."""
    spec12 = ClusterSpec(n_nodes=12)
    assert cluster_timeline(spec12, [720])[0] == 4608


def test_block_assignment_even():
    ev = distribution_evenness(block_assignment(48, 6), 6)
    assert ev["perfectly_even"] and ev["counts"] == [8] * 6


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 200),
    w=st.integers(1, 16),
)
def test_property_block_assignment_covers_all(n, w):
    a = block_assignment(n, w)
    assert a.shape == (n,)
    assert (a >= 0).all() and (a < w).all()
    ev = distribution_evenness(a, w)
    # block assignment is near-even: max-min <= ceil block size
    assert ev["max"] - ev["min"] <= -(-n // w)


@settings(max_examples=30, deadline=None)
@given(
    costs=st.lists(st.floats(0.1, 10.0), min_size=4, max_size=64),
    w=st.integers(2, 8),
)
def test_property_lpt_within_classical_bound(costs, w):
    """LPT is a (4/3 − 1/3w)-approximation — assert the classical bound.

    (LPT can lose to block assignment on adversarial ties, so 'never worse
    than block' is NOT a theorem; the bound below is.)"""
    costs = np.asarray(costs)
    m_lpt = makespan(costs, lpt_assignment(costs, w), w)
    # greedy list-scheduling guarantee: makespan ≤ avg + (1 − 1/w)·max
    assert m_lpt <= costs.sum() / w + (1 - 1 / w) * costs.max() + 1e-6


def test_lpt_beats_block_on_typical_variable_horizons():
    rng = np.random.default_rng(0)
    wins = 0
    for trial in range(20):
        costs = rng.uniform(0.3, 1.0, size=48)
        m_block = makespan(costs, block_assignment(48, 6), 6)
        m_lpt = makespan(costs, lpt_assignment(costs, 6), 6)
        wins += m_lpt <= m_block + 1e-9
    assert wins >= 18  # overwhelmingly better on realistic cost draws


def test_lpt_respects_lower_bound():
    costs = np.asarray([5.0, 3.0, 3.0, 2.0, 2.0, 2.0, 1.0])
    m = makespan(costs, lpt_assignment(costs, 3), 3)
    assert m >= costs.sum() / 3 - 1e-9   # can't beat the average
    assert m >= costs.max() - 1e-9       # can't beat the largest job
    assert m <= costs.sum() / 3 * (4 / 3 - 1 / 9) + costs.max()  # LPT bound
