"""Fleet supervision: retry budgets, quarantine, journal replay, chaos.

The unattended-run half of the paper's §5.2 completion claim: the
supervised loop must survive the full fault taxonomy (crashes, hangs,
stragglers, poison instances, corrupted durable writes) and still bring
every eligible instance to 100 % completion, bit-for-bit equal to a
fault-free run.
"""

import json
import os

import jax
import numpy as np
import pytest
from conftest import assert_states_equal
from hypcompat import given, settings, st

from repro.ckpt import CheckpointManager
from repro.core import SimConfig
from repro.core.fault import FailureInjector, FaultModel, run_with_failures
from repro.core.fleet import (
    FleetState,
    RetryPolicy,
    RunJournal,
    completion_report,
    format_completion_table,
    run_supervised,
)
from repro.core.record import RecordConfig
from repro.core.sweep import SweepConfig, SweepRunner
from repro.data.shards import DatasetWriter, ShardedDataset

SIM = SimConfig(n_slots=16)
MIX = ("highway_merge", "lane_drop")


def _cfg(**kw):
    base = dict(
        n_instances=8,
        steps_per_instance=120,
        chunk_steps=40,
        sim=SIM,
        seed=11,
    )
    base.update(kw)
    return SweepConfig(**base)


# --------------------------------------------------------------------------
# policy / journal / state units
# --------------------------------------------------------------------------

def test_retry_policy_backoff_exponential_and_capped():
    pol = RetryPolicy(max_retries=3, backoff_base=1, backoff_factor=2.0,
                      backoff_cap=5)
    assert [pol.backoff_chunks(k) for k in (1, 2, 3, 4, 5)] == [1, 2, 4, 5, 5]


def test_journal_append_read_and_torn_tail(tmp_path):
    path = str(tmp_path / "j.jsonl")
    j = RunJournal(path)
    j.append({"kind": "chunk", "chunk": 0})
    j.append({"kind": "failure", "chunk": 1})
    # simulate a kill mid-append: torn half line at the tail
    with open(path, "a") as f:
        f.write('{"kind": "chu')
    events = RunJournal.read(path)
    assert [e["kind"] for e in events] == ["chunk", "failure"]
    assert all("time" in e for e in events)


def test_fleet_state_replay_is_assignment(tmp_path):
    events = [
        {"kind": "failure", "chunk": 0, "retries": {"2": 1},
         "hold_until": {"2": 3}},
        {"kind": "failure", "chunk": 4, "retries": {"2": 2, "5": 1},
         "hold_until": {"2": 7, "5": 6}},
        {"kind": "quarantine", "chunk": 5, "instances": [5]},
        {"kind": "chunk", "chunk": 5},
    ]
    fs = FleetState.replay(events, 8)
    assert fs.retries.tolist() == [0, 0, 2, 0, 0, 1, 0, 0]
    assert fs.hold_until.tolist() == [0, 0, 7, 0, 0, 6, 0, 0]
    assert fs.quarantined.tolist() == [False] * 5 + [True] + [False] * 2
    # held = quarantined OR inside the backoff window
    assert fs.held(5).tolist() == [False, False, True, False, False,
                                   True, False, False]
    assert fs.held(7).tolist() == [False] * 5 + [True] + [False] * 2


# --------------------------------------------------------------------------
# supervised loop semantics
# --------------------------------------------------------------------------

@pytest.mark.parametrize("pipeline", [False, True])
def test_supervised_clean_run_matches_plain_run(pipeline):
    clean = SweepRunner(_cfg()).run()
    state, info = run_supervised(SweepRunner(_cfg()), pipeline=pipeline)
    assert info["completion_rate"] == 1.0
    assert info["eligible_completion_rate"] == 1.0
    assert info["quarantined"] == []
    assert_states_equal(clean, state)


def test_supervised_crash_schedule_matches_fault_free(tmp_path):
    """Crashes + backoff only change WHEN instances are stepped, never the
    trajectory: the final state is bit-for-bit the fault-free one."""
    clean = SweepRunner(_cfg()).run()
    fm = FaultModel(4, {0: [1], 1: [0, 3], 3: [2]})
    state, info = run_supervised(
        SweepRunner(_cfg()), fm, RetryPolicy(max_retries=10),
        journal=RunJournal(str(tmp_path / "j.jsonl")),
    )
    assert info["completion_rate"] == 1.0
    assert len(info["failure_events"]) == 3
    assert info["retries_total"] > 0
    assert_states_equal(clean, state._replace(chunk=clean.chunk))


def test_hang_reverts_like_crash_with_distinct_event(tmp_path):
    jr = RunJournal(str(tmp_path / "j.jsonl"))
    fm = FaultModel(4, {}, hangs={0: [1], 2: [2]})
    state, info = run_supervised(SweepRunner(_cfg()), fm, journal=jr)
    assert info["completion_rate"] == 1.0
    kinds = [(e["kind"], e.get("fault")) for e in RunJournal.read(jr.path)]
    assert ("failure", "hang") in kinds
    assert ("failure", "crash") not in kinds
    clean = SweepRunner(_cfg()).run()
    assert_states_equal(clean, state._replace(chunk=clean.chunk))


def test_straggler_keeps_results_and_is_journaled(tmp_path):
    jr = RunJournal(str(tmp_path / "j.jsonl"))
    fm = FaultModel(4, {}, stragglers={0: [1], 1: [3]})
    clean = SweepRunner(_cfg()).run()
    state, info = run_supervised(SweepRunner(_cfg()), fm, journal=jr)
    # no revert: same chunk count as the fault-free run, results kept
    assert info["chunks_run"] == int(jax.device_get(clean.chunk))
    assert info["failure_events"] == []
    assert_states_equal(clean, state)
    evs = RunJournal.read(jr.path)
    assert [e["chunk"] for e in evs if e["kind"] == "straggler"] == [0, 1]


def test_poison_instance_quarantined_rest_completes(tmp_path):
    """One poison instance degrades only itself: it is quarantined after
    exhausting its retry budget, every other instance reaches 100 %."""
    jr = RunJournal(str(tmp_path / "j.jsonl"))
    fm = FaultModel(4, {}, poison_instances=(5,))
    pol = RetryPolicy(max_retries=2, backoff_base=1, backoff_cap=2)
    state, info = run_supervised(
        SweepRunner(_cfg()), fm, pol, journal=jr, max_chunks=80
    )
    assert info["quarantined"] == [5]
    assert info["eligible_completion_rate"] == 1.0
    assert info["completion_rate"] == 7 / 8
    done = np.asarray(jax.device_get(state.done))
    assert not done[5] and done[[i for i in range(8) if i != 5]].all()
    # budget charged exactly: max_retries failures + the quarantining one
    report = info["report"]["total"]
    assert report["retries"] == 3
    evs = RunJournal.read(jr.path)
    assert any(e["kind"] == "quarantine" and e["instances"] == [5]
               for e in evs)
    # the survivors are bit-for-bit the fault-free trajectories
    clean = SweepRunner(_cfg()).run()
    mask = np.ones(8, bool)
    mask[5] = False
    for a, b in zip(jax.tree.leaves(jax.device_get(clean.metrics)),
                    jax.tree.leaves(jax.device_get(state.metrics))):
        np.testing.assert_array_equal(np.asarray(a)[mask],
                                      np.asarray(b)[mask])


def test_backoff_holds_failed_instances_out_of_schedule(tmp_path):
    """After a failure the instance sits out backoff_chunks before being
    re-queued — visible both in the journaled hold horizon and in the
    total chunk count."""
    fm = FaultModel(4, {0: [0]})  # worker 0 = instances 0-1, chunk 0
    pol = RetryPolicy(max_retries=5, backoff_base=2, backoff_factor=1.0,
                      backoff_cap=2)
    jr = RunJournal(str(tmp_path / "j.jsonl"))
    state, info = run_supervised(
        SweepRunner(_cfg()), fm, pol, journal=jr, max_chunks=30
    )
    assert info["completion_rate"] == 1.0
    fail = [e for e in RunJournal.read(jr.path) if e["kind"] == "failure"]
    assert len(fail) == 1
    # failed at chunk 0, backoff 2 → eligible again at chunk 3
    assert fail[0]["hold_until"] == {"0": 3, "1": 3}
    # chunks: 0 (reverted) + 1,2 (others finish, 0-1 held) + 3,4,5
    # (instances 0-1 redo their 3 chunks) = 6 total
    assert info["chunks_run"] == 6


def test_chunk_deadline_overrun_is_journaled_not_fatal(tmp_path):
    """An in-flight jax chunk can't be preempted, so deadline overruns
    degrade to journaled warnings and the run still completes."""
    jr = RunJournal(str(tmp_path / "j.jsonl"))
    _, info = run_supervised(
        SweepRunner(_cfg()), None, journal=jr,
        chunk_deadline=0.0, max_chunks=30,
    )
    assert info["completion_rate"] == 1.0
    deadlines = [e for e in RunJournal.read(jr.path)
                 if e["kind"] == "deadline"]
    assert len(deadlines) == info["chunks_run"]
    assert all(e["elapsed"] > e["deadline"] for e in deadlines)


def test_journal_replay_matches_final_fleet(tmp_path):
    jr = RunJournal(str(tmp_path / "j.jsonl"))
    fm = FaultModel(4, {0: [1], 2: [1]}, poison_instances=(6,))
    pol = RetryPolicy(max_retries=1, backoff_base=1)
    state, info = run_supervised(
        SweepRunner(_cfg()), fm, pol, journal=jr, max_chunks=60
    )
    fs = FleetState.replay(RunJournal.read(jr.path), 8)
    assert np.flatnonzero(fs.quarantined).tolist() == info["quarantined"]
    assert int(fs.retries.sum()) == info["retries_total"]


@pytest.mark.parametrize("pipeline", [False, True])
def test_supervised_kill_resume_parity_under_faults(tmp_path, pipeline):
    """Kill/resume parity for FAULTED sweeps: the fault schedule is keyed
    by the absolute chunk counter, so an interrupted+resumed run replays
    the exact failure history and ends bit-for-bit with the uninterrupted
    one — journal replay restoring the fleet state across the kill."""
    plan = {0: [1], 2: [0, 2], 4: [3]}
    cfg_kw = dict(scenario_mix=MIX, vary_horizon=True, min_horizon_frac=0.4)
    pol = RetryPolicy(max_retries=8, backoff_base=1)

    full, info_full = run_supervised(
        SweepRunner(_cfg(**cfg_kw)), FaultModel(4, dict(plan)), pol,
        pipeline=pipeline,
    )
    assert info_full["completion_rate"] == 1.0

    ck = CheckpointManager(str(tmp_path / "ck"), async_write=False)
    jr = RunJournal(str(tmp_path / "j.jsonl"))
    _, info_a = run_supervised(
        SweepRunner(_cfg(**cfg_kw)), FaultModel(4, dict(plan)), pol,
        ckpt=ck, journal=jr, max_chunks=2, pipeline=pipeline,
    )
    assert info_a["completion_rate"] < 1.0
    resumed, info_b = run_supervised(
        SweepRunner(_cfg(**cfg_kw)), FaultModel(4, dict(plan)), pol,
        ckpt=ck, journal=jr, pipeline=pipeline,
    )
    assert info_b["completion_rate"] == 1.0
    assert_states_equal(full, resumed)
    kinds = [e["kind"] for e in RunJournal.read(jr.path)]
    assert "resume" in kinds


def test_run_with_failures_resume_uses_absolute_chunk(tmp_path):
    """The legacy loop's satellite fix: after a kill/resume the injector
    must be indexed by the restored chunk counter, not the loop index —
    otherwise the resumed run would replay chunk-0 failures again."""
    plan = {0: [1], 2: [0, 3], 3: [2]}
    full, info_full = run_with_failures(
        SweepRunner(_cfg()), FailureInjector(4, dict(plan))
    )
    assert info_full["completion_rate"] == 1.0

    ck = CheckpointManager(str(tmp_path / "ck"), async_write=False)
    run_with_failures(
        SweepRunner(_cfg()), FailureInjector(4, dict(plan)),
        ckpt=ck, max_chunks=2,
    )
    resumed, info = run_with_failures(
        SweepRunner(_cfg()), FailureInjector(4, dict(plan)), ckpt=ck
    )
    assert info["completion_rate"] == 1.0
    # chunk counter included: the resumed schedule replayed 1:1
    assert_states_equal(full, resumed)


# --------------------------------------------------------------------------
# durable-state corruption recovery
# --------------------------------------------------------------------------

def test_corrupt_checkpoint_falls_back_on_resume(tmp_path):
    """An injected checkpoint corruption after chunk 1 must cost at most
    one chunk of progress: resume skips the damaged step, replays from the
    previous valid one, and still ends bit-for-bit correct."""
    fm = FaultModel(4, {}, corrupt_ckpt=frozenset({1}))
    ck = CheckpointManager(str(tmp_path / "ck"), async_write=False)
    jr = RunJournal(str(tmp_path / "j.jsonl"))
    run_supervised(SweepRunner(_cfg()), fm, ckpt=ck, journal=jr,
                   max_chunks=2)
    resumed, info = run_supervised(
        SweepRunner(_cfg()), FaultModel(4, {}), ckpt=ck, journal=jr
    )
    assert info["completion_rate"] == 1.0
    assert ck.last_skipped == [2]  # step 2 (after chunk 1) was damaged
    clean = SweepRunner(_cfg()).run()
    assert_states_equal(clean, resumed)
    evs = RunJournal.read(jr.path)
    assert any(e["kind"] == "resume" and e["skipped_ckpts"] == [2]
               for e in evs)


def _rec_cfg(**kw):
    return _cfg(
        steps_per_instance=80, chunk_steps=40, scenario_mix=MIX,
        record=RecordConfig(record_every=10, k_slots=4), **kw
    )


def test_corrupt_shard_detected_and_rewritten(tmp_path):
    """An injected shard truncation is caught by the per-chunk
    verify_shards audit and the instances are re-drained — the final
    dataset is complete and bit-for-bit equal to an undamaged run."""
    cfg = _rec_cfg()
    jr = RunJournal(str(tmp_path / "j.jsonl"))
    wr = DatasetWriter(str(tmp_path / "ds"), cfg, shard_size=2)
    fm = FaultModel(4, {}, corrupt_shard=frozenset({1}))
    state, info = run_supervised(
        SweepRunner(cfg), fm, writer=wr, journal=jr
    )
    assert info["completion_rate"] == 1.0
    wr.finalize()
    ds = ShardedDataset.load(str(tmp_path / "ds"))
    assert ds.n_instances == 8
    evs = RunJournal.read(jr.path)
    assert any(e["kind"] == "corrupt_shard" for e in evs)
    assert any(e["kind"] == "shard_repair" for e in evs)
    assert ds.manifest["repaired_shards"] != []

    # parity with an undamaged recording run
    wr2 = DatasetWriter(str(tmp_path / "ds2"), cfg, shard_size=2)
    run_supervised(SweepRunner(cfg), writer=wr2)
    wr2.finalize()
    ds2 = ShardedDataset.load(str(tmp_path / "ds2"))
    for a, b in zip(ds.series()[1:], ds2.series()[1:]):
        np.testing.assert_array_equal(a, b)


def test_supervised_shard_parity_under_fault_storm(tmp_path):
    """Recording + crashes + hangs + poison: the persisted dataset rows of
    every non-quarantined instance match the fault-free dataset exactly."""
    cfg = _rec_cfg(vary_horizon=True, min_horizon_frac=0.4)
    clean_wr = DatasetWriter(str(tmp_path / "clean"), cfg, shard_size=4)
    run_supervised(SweepRunner(cfg), writer=clean_wr)
    clean_wr.finalize()

    fm = FaultModel(4, {1: [0]}, hangs={2: [2]}, poison_instances=(7,),
                    corrupt_shard=frozenset({3}))
    wr = DatasetWriter(str(tmp_path / "faulted"), cfg, shard_size=4)
    state, info = run_supervised(
        SweepRunner(cfg), fm, RetryPolicy(max_retries=2, backoff_cap=2),
        writer=wr, journal=RunJournal(str(tmp_path / "j.jsonl")),
        max_chunks=80,
    )
    assert info["quarantined"] == [7]
    assert info["eligible_completion_rate"] == 1.0
    wr.finalize()

    clean = ShardedDataset.load(str(tmp_path / "clean"))
    faulted = ShardedDataset.load(str(tmp_path / "faulted"))
    by_id = {}
    for shard in clean.iter_shards():
        for row, i in enumerate(shard["instance"]):
            by_id[int(i)] = {k: v[row] for k, v in shard.items()}
    seen = set()
    for shard in faulted.iter_shards():
        for row, i in enumerate(shard["instance"]):
            seen.add(int(i))
            for k, v in shard.items():
                np.testing.assert_array_equal(v[row], by_id[int(i)][k])
    assert seen == set(range(8)) - {7}


# --------------------------------------------------------------------------
# completion report (§5.2)
# --------------------------------------------------------------------------

def test_completion_report_and_table():
    cfg = _cfg(scenario_mix=MIX)
    state, info = run_supervised(SweepRunner(cfg))
    report = completion_report(state, None, cfg.scenarios)
    assert report["total"]["completion_rate"] == 1.0
    assert {r["scenario"] for r in report["scenarios"]} == set(MIX)
    assert all(r["instances"] == 4 for r in report["scenarios"])
    table = format_completion_table(report)
    assert "100.0%" in table and "| total |" in table
    for name in MIX:
        assert f"| {name} |" in table
    assert json.dumps(info["report"])  # JSON-serializable end to end


# --------------------------------------------------------------------------
# hypothesis chaos schedules
# --------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_random_chaos_schedule_completes(seed):
    """Any random crash/hang schedule (back-to-back failures, failure on
    the final chunk, whole-fleet kills included) still reaches 100 %
    completion with the fault-free bits."""
    fm = FaultModel.random_model(
        n_workers=4, n_chunks=12, fail_prob=0.25, hang_prob=0.15,
        straggler_prob=0.2, seed=seed,
    )
    state, info = run_supervised(
        SweepRunner(_cfg()), fm, RetryPolicy(max_retries=50, backoff_cap=2),
        max_chunks=120,
    )
    assert info["completion_rate"] == 1.0
    clean = SweepRunner(_cfg()).run()
    assert_states_equal(clean, state._replace(chunk=clean.chunk))


@settings(max_examples=4, deadline=None)
@given(kill_after=st.integers(1, 4), seed=st.integers(0, 1000))
def test_property_chaos_plus_kill_resume_parity(tmp_path_factory, kill_after,
                                                seed):
    """Chaos schedule + a process kill at an arbitrary chunk: the resumed
    run ends bit-for-bit with the uninterrupted chaos run — including a
    failure landing on the very chunk the kill interrupts."""
    tmp = tmp_path_factory.mktemp("fleet")
    fm_args = dict(n_workers=4, n_chunks=10, fail_prob=0.3, hang_prob=0.1,
                   seed=seed)
    pol = RetryPolicy(max_retries=50, backoff_cap=1)
    full, _ = run_supervised(
        SweepRunner(_cfg()), FaultModel.random_model(**fm_args), pol,
        max_chunks=120,
    )
    ck = CheckpointManager(str(tmp / "ck"), async_write=False)
    jr = RunJournal(str(tmp / "j.jsonl"))
    run_supervised(
        SweepRunner(_cfg()), FaultModel.random_model(**fm_args), pol,
        ckpt=ck, journal=jr, max_chunks=kill_after,
    )
    resumed, info = run_supervised(
        SweepRunner(_cfg()), FaultModel.random_model(**fm_args), pol,
        ckpt=ck, journal=jr, max_chunks=120,
    )
    assert info["completion_rate"] == 1.0
    assert_states_equal(full, resumed)
