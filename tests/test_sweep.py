"""Sweep engine tests: completion, chunking invariance, compaction, tokens,
trajectory recording, and plan_chunk/GroupPlan property-based invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core import SimConfig
from repro.core.record import RecordConfig
from repro.core.sweep import (
    SweepConfig,
    SweepRunner,
    completion_rate,
    plan_chunk,
)
from repro.core.tokens import (
    record_rollout,
    trajectory_to_tokens,
    sweep_token_dataset,
    vocab_size,
    BOS, EOS, SEP,
)
from repro.core.aggregate import aggregate_metrics, metrics_to_records
from repro.core.scenario import sample_scenario_params

SIM = SimConfig(n_slots=16)


def _cfg(**kw):
    base = dict(
        n_instances=6,
        steps_per_instance=120,
        chunk_steps=40,
        sim=SIM,
        seed=3,
    )
    base.update(kw)
    return SweepConfig(**base)


def test_sweep_runs_to_completion():
    runner = SweepRunner(_cfg())
    state = runner.run()
    assert completion_rate(state) == 1.0
    assert int(jax.device_get(state.chunk)) == 3  # 120/40


def test_sweep_chunk_size_invariance():
    """Results must not depend on the walltime-slice size (checkpointable)."""
    s1 = SweepRunner(_cfg(chunk_steps=40)).run()
    s2 = SweepRunner(_cfg(chunk_steps=120)).run()
    s3 = SweepRunner(_cfg(chunk_steps=24)).run()
    for a, b in zip(jax.tree.leaves(s1.metrics), jax.tree.leaves(s2.metrics)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(s1.metrics), jax.tree.leaves(s3.metrics)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sweep_compaction_matches_plain():
    """Straggler compaction is an optimization, never a semantic change."""
    varied = dict(vary_horizon=True, min_horizon_frac=0.3)
    s1 = SweepRunner(_cfg(compaction=True, **varied)).run()
    s2 = SweepRunner(_cfg(compaction=False, **varied)).run()
    for a, b in zip(jax.tree.leaves(s1.metrics), jax.tree.leaves(s2.metrics)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert completion_rate(s1) == 1.0


def test_sweep_variable_horizons_complete():
    runner = SweepRunner(_cfg(vary_horizon=True, min_horizon_frac=0.25))
    state = runner.run()
    assert completion_rate(state) == 1.0
    t = np.asarray(jax.device_get(state.sim.t))
    h = np.asarray(jax.device_get(state.horizon))
    assert np.all(t >= h)  # every instance reached its own horizon


def test_aggregate_and_records():
    runner = SweepRunner(_cfg())
    state = runner.run()
    summary = aggregate_metrics(state.metrics)
    assert summary["instances"] == 6
    assert summary["total_sim_steps"] == 6 * 120
    recs = metrics_to_records(state.metrics, state.params)
    assert len(recs) == 6
    assert all("p_cav" in r and 0.0 <= r["p_cav"] <= 1.0 for r in recs)


def test_token_stream_roundtrip_structure():
    key = jax.random.key(0)
    sp = sample_scenario_params(jax.random.key(1), SIM)
    _, traj = record_rollout(key, sp, SIM, n_steps=50, record_every=10,
                             k_slots=8)
    toks = trajectory_to_tokens(traj, SIM)
    toks = np.asarray(toks)
    assert toks[0] == BOS and toks[-1] == EOS
    assert (toks < vocab_size(SIM)).all() and (toks >= 0).all()
    # 5 frames x (8 vehicle tokens + SEP) + BOS + EOS
    assert toks.shape[0] == 5 * 9 + 2
    assert (toks == SEP).sum() == 5


MIX = ("highway_merge", "lane_drop", "stop_and_go", "speed_limit_zone")


def test_mixed_scenario_sweep_completes_with_groups():
    """A 4-scenario mix runs to 100% under ONE compiled chunk program and
    aggregates per-scenario."""
    cfg = _cfg(n_instances=8, scenario_mix=MIX)
    runner = SweepRunner(cfg)
    state = runner.run()
    assert completion_rate(state) == 1.0
    ids = np.asarray(jax.device_get(state.scenario_id))
    np.testing.assert_array_equal(ids, np.arange(8) % 4)
    summary = aggregate_metrics(
        state.metrics, scenario_ids=state.scenario_id,
        scenario_names=cfg.scenarios,
    )
    assert set(summary["per_scenario"]) == set(MIX)
    for name in MIX:
        assert summary["per_scenario"][name]["instances"] == 2
        assert summary["per_scenario"][name]["total_sim_steps"] == 2 * 120
    # ring scenarios surface their aliased gauge names
    assert "total_stopped_steps" in summary["per_scenario"]["stop_and_go"]
    recs = metrics_to_records(
        state.metrics, state.params,
        scenario_ids=state.scenario_id, scenario_names=cfg.scenarios,
    )
    assert [r["scenario"] for r in recs[:4]] == list(MIX)
    assert "forced_merges" in [r for r in recs if r["scenario"] == "lane_drop"][0]


def test_mixed_sweep_matches_single_scenario_runs():
    """Instance i of a mixed sweep must equal instance i of... itself run
    under the same seed path: mixing changes WHICH scenario an instance
    runs, never the instance's PRNG stream. Cross-check one scenario: a
    mixed sweep's highway_merge instances reproduce the same metrics as a
    uniform highway_merge sweep's instances at the same instance ids."""
    mixed = SweepRunner(_cfg(n_instances=8, scenario_mix=MIX)).run()
    uniform = SweepRunner(_cfg(n_instances=8)).run()  # all highway_merge
    for i in range(0, 8, 4):  # instances 0 and 4 are highway_merge in MIX
        for a, b in zip(
            jax.tree.leaves(jax.tree.map(lambda x: x[i], mixed.metrics)),
            jax.tree.leaves(jax.tree.map(lambda x: x[i], uniform.metrics)),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_weighted_mix_groups_by_name():
    """A mix listing a scenario twice (weighted demand) must aggregate ALL
    of that scenario's instances into one per-scenario group."""
    mix = ("stop_and_go", "stop_and_go", "highway_merge")
    cfg = _cfg(n_instances=6, scenario_mix=mix)
    state = SweepRunner(cfg).run()
    summary = aggregate_metrics(
        state.metrics, scenario_ids=state.scenario_id,
        scenario_names=cfg.scenarios,
    )
    per = summary["per_scenario"]
    assert set(per) == {"stop_and_go", "highway_merge"}
    assert per["stop_and_go"]["instances"] == 4      # roster slots 0 and 1
    assert per["highway_merge"]["instances"] == 2
    assert per["stop_and_go"]["total_sim_steps"] == 4 * 120


def test_mixed_sweep_chunk_size_invariance():
    s1 = SweepRunner(_cfg(n_instances=4, scenario_mix=MIX, chunk_steps=40)).run()
    s2 = SweepRunner(_cfg(n_instances=4, scenario_mix=MIX, chunk_steps=120)).run()
    for a, b in zip(jax.tree.leaves(s1.metrics), jax.tree.leaves(s2.metrics)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_single_scenario_sweep_any_registered():
    for name in ("lane_drop", "speed_limit_zone"):
        cfg = _cfg(n_instances=2, sim=SimConfig(n_slots=16, scenario=name))
        state = SweepRunner(cfg).run()
        assert completion_rate(state) == 1.0


from conftest import assert_states_equal as _assert_states_equal


@pytest.mark.parametrize("compaction", [True, False])
@pytest.mark.parametrize("varied", [{}, dict(vary_horizon=True,
                                             min_horizon_frac=0.3)])
def test_grouped_matches_switch_bitwise(compaction, varied):
    """Grouped dispatch is an optimization, never a semantic change: the
    ENTIRE final SweepState tree is bit-for-bit equal to switch dispatch."""
    kw = dict(n_instances=8, scenario_mix=MIX, compaction=compaction, **varied)
    sw = SweepRunner(_cfg(dispatch="switch", **kw)).run()
    gr = SweepRunner(_cfg(dispatch="grouped", **kw)).run()
    assert completion_rate(gr) == 1.0
    _assert_states_equal(sw, gr)


def test_dispatch_auto_resolution():
    assert SweepConfig(scenario_mix=MIX).effective_dispatch == "grouped"
    assert SweepConfig().effective_dispatch == "switch"
    assert SweepConfig(scenario_mix=MIX,
                       dispatch="switch").effective_dispatch == "switch"
    assert SweepConfig(dispatch="grouped").effective_dispatch == "grouped"
    with pytest.raises(ValueError):
        SweepRunner(_cfg(dispatch="bogus"))


def test_grouped_single_scenario_and_weighted_mix():
    """Grouped dispatch also works off the mixed-sweep happy path."""
    uni = SweepRunner(_cfg(dispatch="grouped")).run()
    assert completion_rate(uni) == 1.0
    _assert_states_equal(uni, SweepRunner(_cfg(dispatch="switch")).run())

    mix = ("stop_and_go", "stop_and_go", "highway_merge")
    kw = dict(n_instances=6, scenario_mix=mix)
    _assert_states_equal(SweepRunner(_cfg(dispatch="grouped", **kw)).run(),
                         SweepRunner(_cfg(dispatch="switch", **kw)).run())


def test_plan_chunk_groups_and_padding():
    """Planner unit behavior: per-scenario partition of the pending set,
    padded to worker multiples with already-done instances."""
    done = np.array([False, True, False, False, True, False])
    sids = np.arange(6) % 2
    plans = plan_chunk(done, sids, 4, grouped=True, compaction=True)
    assert [p.roster for p in plans] == [0, 1]
    np.testing.assert_array_equal(plans[0].take[: plans[0].keep], [0, 2])
    np.testing.assert_array_equal(plans[1].take[: plans[1].keep], [3, 5])
    for p in plans:
        assert p.take.size == 4 and not p.identity
        # padding rows are drawn from the done pool, not live duplicates
        assert set(p.take[p.keep:]) <= {1, 4}
        assert done[p.take[p.keep:]].all()


def test_plan_chunk_padding_without_done_pool():
    """First chunk (nothing finished yet): fall back to repeating a live row."""
    done = np.zeros(3, bool)
    plans = plan_chunk(done, np.zeros(3, np.int64), 2, grouped=False,
                       compaction=True)
    (p,) = plans
    assert p.take.size == 4 and p.keep == 3
    assert p.take[-1] == p.take[0]


def test_plan_chunk_empty_and_identity():
    assert plan_chunk(np.ones(4, bool), np.zeros(4, np.int64), 2,
                      grouped=True, compaction=True) == []
    # compaction off, single group, no padding needed -> identity fast path
    (p,) = plan_chunk(np.zeros(4, bool), np.zeros(4, np.int64), 2,
                      grouped=False, compaction=False)
    assert p.identity and p.keep == 4


def test_sweep_token_dataset_shapes():
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.key(0), i))(
        jnp.arange(3)
    )
    params = jax.vmap(
        lambda k: sample_scenario_params(k, SIM)
    )(keys)
    ds = sweep_token_dataset(keys, params, SIM, n_steps=40, record_every=10,
                             k_slots=4)
    assert ds.shape[0] == 3
    assert ds.shape[1] == 4 * 5 + 2  # 4 frames x (4+1) + BOS/EOS
    # instances deviate (the paper's randomization premise)
    assert not np.array_equal(np.asarray(ds[0]), np.asarray(ds[1]))


# --------------------------------------------------------------------------
# trajectory recording (repro.core.record): dispatch parity by construction
# --------------------------------------------------------------------------

REC = RecordConfig(record_every=10, k_slots=4)
MIX2 = ("highway_merge", "lane_drop")
_REC_KW = dict(n_instances=6, steps_per_instance=60, chunk_steps=30,
               scenario_mix=MIX2, record=REC, vary_horizon=True,
               min_horizon_frac=0.3)
_REC_REF: dict = {}  # dispatch-parity reference state, computed once


def _rec_ref():
    if "state" not in _REC_REF:
        _REC_REF["state"] = SweepRunner(
            _cfg(dispatch="switch", compaction=True, **_REC_KW)
        ).run()
    return _REC_REF["state"]


def test_recording_chunk_size_invariance():
    """Rows are indexed by absolute step count, so chunk boundaries cannot
    change a single recorded bit (the slice counter itself legitimately
    differs, so it is normalized out of the comparison). chunk 24 is not a
    stride multiple, so this also pins windowed-vs-per-step recording
    parity (the two code paths inside rollout_chunk_rec)."""
    ref = _rec_ref()
    for chunk in (60, 20, 24):
        got = SweepRunner(
            _cfg(dispatch="switch", compaction=True,
                 **{**_REC_KW, "chunk_steps": chunk})
        ).run()
        _assert_states_equal(ref, got._replace(chunk=ref.chunk))


@pytest.mark.parametrize("dispatch,compaction", [
    ("grouped", True), ("grouped", False), ("switch", False), ("auto", True),
])
def test_recording_dispatch_parity_bitwise(dispatch, compaction):
    """Recorded time series are bit-identical across every dispatch mode ×
    compaction setting: the trace rides SweepState through the planner's
    logical-slot scatter, so physical repacking can never leak into it."""
    got = SweepRunner(
        _cfg(dispatch=dispatch, compaction=compaction, **_REC_KW)
    ).run()
    assert completion_rate(got) == 1.0
    _assert_states_equal(_rec_ref(), got)


def test_recording_matches_record_rollout_oracle():
    """The sweep recorder reproduces tokens.record_rollout's trajectory
    bit-for-bit when pointed at the same instance PRNG path — the recorder
    changes WHERE rows are stored, never what is simulated."""
    from repro.core.scenarios import get_scenario

    cfg = _cfg(record=REC, steps_per_instance=60, chunk_steps=30,
               n_instances=2)
    state = SweepRunner(cfg).run()
    base = jax.random.key(cfg.seed)
    for i in range(2):
        k = jax.random.fold_in(base, i)
        sp = get_scenario(SIM.scenario).sample_params(
            jax.random.fold_in(k, 1), SIM
        )
        _, traj = record_rollout(
            jax.random.fold_in(k, 2), sp, SIM,
            n_steps=cfg.steps_per_instance,
            record_every=REC.record_every, k_slots=REC.k_slots,
        )
        tr = jax.tree.map(lambda x: np.asarray(x[i]), state.trace)
        np.testing.assert_array_equal(np.asarray(traj.lane), tr.lane)
        np.testing.assert_array_equal(np.asarray(traj.speed), tr.speed)
        np.testing.assert_array_equal(np.asarray(traj.active), tr.active)


def test_recording_rows_beyond_horizon_stay_zero():
    """Variable-cost instances fill exactly horizon // record_every rows."""
    state = _rec_ref()
    tr = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state.trace)
    h = np.asarray(jax.device_get(state.horizon))
    assert (h < _REC_KW["steps_per_instance"]).any()  # real stragglers
    for i, hi in enumerate(h):
        v = hi // REC.record_every
        assert tr.active[i, v:].sum() == 0
        assert (tr.series[i, v:] == 0).all()
        # filled rows carry real data: the active-count channel is populated
        assert (tr.series[i, :v, 1] > 0).any()


def test_record_config_validation():
    with pytest.raises(ValueError):
        RecordConfig(record_every=0)
    with pytest.raises(ValueError):
        RecordConfig(fields=("no_such_channel",))
    with pytest.raises(ValueError):
        RecordConfig(fields=(), k_slots=0)
    with pytest.raises(ValueError):
        RecordConfig(k_slots=-1)
    assert RecordConfig().n_rows(120) == 12
    assert RecordConfig(record_every=7).n_rows(120) == 17


# --------------------------------------------------------------------------
# plan_chunk / GroupPlan property-based invariants (hypothesis)
# --------------------------------------------------------------------------


def _check_plan_invariants(done, sids, n_workers, grouped, compaction,
                           n_scenarios):
    plans = plan_chunk(done, sids, n_workers, grouped=grouped,
                       compaction=compaction)
    n = done.size
    pending = np.flatnonzero(~done)
    expected = pending if compaction else np.arange(n)
    if compaction and pending.size == 0:
        assert plans == []
        return plans
    # every scheduled-for-keep instance appears EXACTLY once across groups
    kept = np.concatenate([p.take[: p.keep] for p in plans])
    assert sorted(kept.tolist()) == sorted(expected.tolist())
    done_pool = np.flatnonzero(done)
    for p in plans:
        # dense groups: padded to a worker multiple
        assert p.take.size % n_workers == 0 and p.take.size > 0
        pad = p.take[p.keep:]
        if done_pool.size:
            # padding rows come only from already-done instances
            assert done[pad].all()
        else:
            # fallback: repeat a live row of the same group
            assert set(pad.tolist()) <= set(p.take[: p.keep].tolist())
        if grouped:
            assert 0 <= p.roster < n_scenarios
            assert (sids[p.take[: p.keep]] == p.roster).all()
        else:
            assert p.roster == -1
        assert p.identity == (
            p.take.size == n and p.keep == n
            and np.array_equal(p.take, np.arange(n))
        )
    return plans


@settings(max_examples=80, deadline=None)
@given(
    n=st.integers(1, 40),
    n_workers=st.integers(1, 9),
    n_scenarios=st.integers(1, 5),
    grouped=st.booleans(),
    compaction=st.booleans(),
    seed=st.integers(0, 2**32 - 1),
)
def test_property_plan_chunk_invariants(n, n_workers, n_scenarios, grouped,
                                        compaction, seed):
    """Every pending instance is scheduled exactly once; padding rows are
    drawn only from done instances (or group-live fallback); groups are
    dense worker multiples partitioned by scenario."""
    rng = np.random.default_rng(seed)
    done = rng.random(n) < rng.uniform(0.0, 1.0)
    sids = rng.integers(0, n_scenarios, size=n)
    _check_plan_invariants(done, sids, n_workers, grouped, compaction,
                           n_scenarios)


@settings(max_examples=80, deadline=None)
@given(
    n=st.integers(1, 40),
    n_workers=st.integers(1, 9),
    n_scenarios=st.integers(1, 5),
    grouped=st.booleans(),
    compaction=st.booleans(),
    seed=st.integers(0, 2**32 - 1),
)
def test_property_scatter_roundtrip_identity(n, n_workers, n_scenarios,
                                             grouped, compaction, seed):
    """The gather → per-group transform → scatter pipeline applies the
    transform to every live slot exactly once and is the identity on every
    other slot (what makes recording dispatch-agnostic)."""
    rng = np.random.default_rng(seed)
    done = rng.random(n) < rng.uniform(0.0, 1.0)
    sids = rng.integers(0, n_scenarios, size=n)
    plans = plan_chunk(done, sids, n_workers, grouped=grouped,
                       compaction=compaction)
    base = rng.normal(size=n)
    out = base.copy()
    for p in plans:
        part = out[p.take] + 1.0       # the "chunk step" on physical rows
        out[p.take[: p.keep]] = part[: p.keep]  # padding rows dropped
    live = ~done if compaction else np.ones(n, bool)
    np.testing.assert_allclose(out[live], base[live] + 1.0)
    np.testing.assert_array_equal(out[~live], base[~live])
