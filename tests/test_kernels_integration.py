"""End-to-end kernel integration: whole-model forward with the Pallas flash
attention swapped in (interpret mode) must match the XLA attention path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch
from repro.models import build_model
from repro.models.attention import attention_impl


@pytest.mark.parametrize("arch", ["gemma2-2b", "qwen1.5-0.5b"])
def test_model_forward_with_pallas_attention(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 128), 0,
                                cfg.vocab_size)
    ref_logits, _ = model.apply(params, {"tokens": tokens})
    with attention_impl("pallas"):
        ker_logits, _ = model.apply(params, {"tokens": tokens})
    np.testing.assert_allclose(
        np.asarray(ker_logits), np.asarray(ref_logits), rtol=3e-2, atol=3e-2
    )


def test_pallas_path_covers_local_and_softcap():
    """gemma2 exercises sliding window + softcap inside the kernel."""
    cfg = get_arch("gemma2-2b").reduced(window=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    tokens = jax.random.randint(jax.random.key(3), (1, 256), 0,
                                cfg.vocab_size)
    ref_logits, _ = model.apply(params, {"tokens": tokens})
    with attention_impl("pallas"):
        ker_logits, _ = model.apply(params, {"tokens": tokens})
    np.testing.assert_allclose(
        np.asarray(ker_logits), np.asarray(ref_logits), rtol=3e-2, atol=3e-2
    )
