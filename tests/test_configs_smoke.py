"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned architectures: instantiate the REDUCED config of
the same family, run one forward/train step on CPU, assert output shapes and
no NaNs. Also exercises one prefill+decode step per arch.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch
from repro.configs import ALL_ARCHS
from repro.models import build_model
from repro.models.frontends import audio_frames_stub, vision_stream_stub

B, S = 2, 32


def _batch(model, key):
    cfg = model.cfg
    if cfg.is_encdec:
        return {
            "frames": audio_frames_stub(key, cfg, B, cfg.enc_ctx),
            "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        }
    if cfg.mrope_sections:
        tokens, mrope = vision_stream_stub(key, cfg, B, S)
        return {"tokens": tokens, "mrope_pos": mrope}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(model, jax.random.key(1))
    logits, aux = jax.jit(model.apply)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))
    if cfg.n_experts > 0:
        assert float(aux) > 0.0  # load-balance loss engaged


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    """One gradient step moves the loss (tests autodiff through every family)."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(model, jax.random.key(1))
    labels = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)

    def loss_fn(p):
        logits, aux = model.apply(p, batch, remat="full")
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads))
    )
    assert float(gnorm) > 0.0 and np.isfinite(float(gnorm))

    # SGD step reduces this batch's loss
    lr = 0.5
    params2 = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)
                      ).astype(p.dtype),
        params, grads,
    )
    loss2 = jax.jit(loss_fn)(params2)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(model, jax.random.key(1))
    max_seq = S + 8
    cache = model.init_cache(B, max_seq)
    logits, cache = jax.jit(model.prefill)(params, cache, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()

    nxt = jnp.argmax(logits, axis=-1)
    pos = jnp.full((B,), S, jnp.int32)
    logits2, cache = jax.jit(model.decode)(params, cache, nxt, pos)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize(
    "arch", ["gemma2-2b", "rwkv6-3b", "recurrentgemma-2b", "minicpm3-4b"]
)
def test_decode_matches_full_forward(arch):
    """Token-by-token decode must reproduce the teacher-forced forward."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (1, 12), 0, cfg.vocab_size)
    full_logits, _ = jax.jit(model.apply)(params, {"tokens": tokens})

    cache = model.init_cache(1, 16)
    step = jax.jit(model.decode)
    logits_seq = []
    for t in range(12):
        logits, cache = step(
            params, cache, tokens[:, t], jnp.array([t], jnp.int32)
        )
        logits_seq.append(logits)
    dec = jnp.stack(logits_seq, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full_logits), rtol=2e-2, atol=2e-2
    )
