"""Checkpoint I/O: roundtrip, atomicity, retention, dtype restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.ckpt import CheckpointManager, save_pytree, load_pytree, latest_step
from repro.ckpt.io import load_meta


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "w": jax.random.normal(k, (4, 8), jnp.float32),
        "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
        "scalar": jnp.float32(3.5),
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_pytree(str(tmp_path / "c"), tree, meta={"step": 7})
    out = load_pytree(str(tmp_path / "c"), like=tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert load_meta(str(tmp_path / "c"))["step"] == 7


def test_roundtrip_with_shapedtypestruct_like(tmp_path):
    tree = _tree()
    save_pytree(str(tmp_path / "c"), tree)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )
    out = load_pytree(str(tmp_path / "c"), like=like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_leaf_count_mismatch_raises(tmp_path):
    save_pytree(str(tmp_path / "c"), _tree())
    with pytest.raises(ValueError):
        load_pytree(str(tmp_path / "c"), like={"only": jnp.zeros(3)})


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _tree(s))
    assert latest_step(str(tmp_path)) == 4
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_000000003", "step_000000004"]
    restored, meta = mgr.restore(like=_tree())
    assert meta["step"] == 4
    for a, b in zip(jax.tree.leaves(_tree(4)), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manager_async_write_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(10, _tree(10))
    restored, meta = mgr.restore(like=_tree())  # restore barriers on writer
    assert meta["step"] == 10


@settings(max_examples=10, deadline=None)
@given(
    shape=st.tuples(st.integers(1, 5), st.integers(1, 5)),
    dtype=st.sampled_from(["float32", "int32", "bfloat16"]),
)
def test_property_any_shape_dtype_roundtrips(tmp_path_factory, shape, dtype):
    tmp = tmp_path_factory.mktemp("ck")
    x = jnp.ones(shape, dtype=dtype) * 3
    save_pytree(str(tmp / "c"), {"x": x})
    out = load_pytree(str(tmp / "c"), like={"x": x})
    np.testing.assert_array_equal(
        np.asarray(out["x"], dtype=np.float32),
        np.asarray(x, dtype=np.float32),
    )
    assert out["x"].dtype == x.dtype


# --------------------------------------------------------------------------
# crash-atomic saves + integrity-validated restore (the unattended-run
# durable-state contract, paper §5.2)
# --------------------------------------------------------------------------

import json
import shutil

from repro.ckpt import valid_steps, verify_checkpoint
from repro.ckpt.io import MANIFEST, PAYLOAD


def _step_dir(root, step):
    return os.path.join(str(root), f"step_{step:09d}")


def test_verify_checkpoint_detects_truncation_and_missing(tmp_path):
    path = str(tmp_path / "c")
    save_pytree(path, _tree(), meta={"step": 1})
    assert verify_checkpoint(path)
    # truncated payload: digest mismatch
    payload = os.path.join(path, PAYLOAD)
    with open(payload, "r+b") as f:
        f.truncate(os.path.getsize(payload) // 2)
    assert not verify_checkpoint(path)
    # missing payload
    os.remove(payload)
    assert not verify_checkpoint(path)
    # missing / unparseable manifest
    assert not verify_checkpoint(str(tmp_path / "nope"))
    os.makedirs(str(tmp_path / "torn"))
    with open(os.path.join(str(tmp_path / "torn"), MANIFEST), "w") as f:
        f.write('{"leaves": [')
    assert not verify_checkpoint(str(tmp_path / "torn"))


def test_restore_falls_back_past_corrupt_newest(tmp_path):
    """A corrupted newest checkpoint costs one step, never the run: the
    manager skips it (recording the skip) and restores the previous valid
    step; has_checkpoint likewise refuses to count it."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    for s in (1, 2, 3):
        mgr.save(s, _tree(s))
    payload = os.path.join(_step_dir(tmp_path, 3), PAYLOAD)
    with open(payload, "r+b") as f:
        f.truncate(os.path.getsize(payload) // 2)

    assert valid_steps(str(tmp_path)) == [1, 2]
    assert mgr.has_checkpoint()
    restored, meta = mgr.restore(like=_tree())
    assert meta["step"] == 2
    assert mgr.last_skipped == [3]
    for a, b in zip(jax.tree.leaves(_tree(2)), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_explicit_corrupt_step_is_strict(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    payload = os.path.join(_step_dir(tmp_path, 2), PAYLOAD)
    with open(payload, "r+b") as f:
        f.truncate(1)
    with pytest.raises(FileNotFoundError):
        mgr.restore(like=_tree(), step=2)
    # the newest-valid walk still works
    _, meta = mgr.restore(like=_tree())
    assert meta["step"] == 1


def test_all_checkpoints_corrupt_raises_listing_skips(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    for s in (1, 2):
        mgr.save(s, _tree(s))
        payload = os.path.join(_step_dir(tmp_path, s), PAYLOAD)
        os.remove(payload)
    assert not mgr.has_checkpoint()
    with pytest.raises(FileNotFoundError) as e:
        mgr.restore(like=_tree())
    assert mgr.last_skipped == [2, 1]
    assert "skipped corrupt steps" in str(e.value)


def test_mid_save_kill_artifacts_are_invisible_and_gced(tmp_path):
    """A staging dir left by a SIGKILLed writer is never mistaken for a
    checkpoint and is swept by the next save's gc."""
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, _tree(1))
    # fake a killed writer: stale staging dir with a full payload inside
    stale = os.path.join(str(tmp_path), ".tmp-step_000000002-99999")
    shutil.copytree(_step_dir(tmp_path, 1), stale)
    assert latest_step(str(tmp_path)) == 1  # staging never counts
    restored, meta = mgr.restore(like=_tree())
    assert meta["step"] == 1
    mgr.save(3, _tree(3))
    assert not os.path.exists(stale)  # swept
    assert latest_step(str(tmp_path)) == 3


def test_save_overwrites_same_step_atomically(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(5, _tree(1))
    mgr.save(5, _tree(2))
    restored, meta = mgr.restore(like=_tree(), step=5)
    assert meta["step"] == 5
    for a, b in zip(jax.tree.leaves(_tree(2)), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_legacy_digestless_manifest_still_verifies(tmp_path):
    """Pre-digest checkpoints (no payload_sha256 key) must keep restoring:
    verification skips the digest check instead of rejecting them."""
    path = str(tmp_path / "c")
    save_pytree(path, _tree(), meta={"step": 1})
    mpath = os.path.join(path, MANIFEST)
    with open(mpath) as f:
        manifest = json.load(f)
    del manifest["payload_sha256"]
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    assert verify_checkpoint(path)
    load_pytree(path, like=_tree())
