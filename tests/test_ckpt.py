"""Checkpoint I/O: roundtrip, atomicity, retention, dtype restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.ckpt import CheckpointManager, save_pytree, load_pytree, latest_step
from repro.ckpt.io import load_meta


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "w": jax.random.normal(k, (4, 8), jnp.float32),
        "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
        "scalar": jnp.float32(3.5),
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_pytree(str(tmp_path / "c"), tree, meta={"step": 7})
    out = load_pytree(str(tmp_path / "c"), like=tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert load_meta(str(tmp_path / "c"))["step"] == 7


def test_roundtrip_with_shapedtypestruct_like(tmp_path):
    tree = _tree()
    save_pytree(str(tmp_path / "c"), tree)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )
    out = load_pytree(str(tmp_path / "c"), like=like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_leaf_count_mismatch_raises(tmp_path):
    save_pytree(str(tmp_path / "c"), _tree())
    with pytest.raises(ValueError):
        load_pytree(str(tmp_path / "c"), like={"only": jnp.zeros(3)})


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _tree(s))
    assert latest_step(str(tmp_path)) == 4
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_000000003", "step_000000004"]
    restored, meta = mgr.restore(like=_tree())
    assert meta["step"] == 4
    for a, b in zip(jax.tree.leaves(_tree(4)), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manager_async_write_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(10, _tree(10))
    restored, meta = mgr.restore(like=_tree())  # restore barriers on writer
    assert meta["step"] == 10


@settings(max_examples=10, deadline=None)
@given(
    shape=st.tuples(st.integers(1, 5), st.integers(1, 5)),
    dtype=st.sampled_from(["float32", "int32", "bfloat16"]),
)
def test_property_any_shape_dtype_roundtrips(tmp_path_factory, shape, dtype):
    tmp = tmp_path_factory.mktemp("ck")
    x = jnp.ones(shape, dtype=dtype) * 3
    save_pytree(str(tmp / "c"), {"x": x})
    out = load_pytree(str(tmp / "c"), like={"x": x})
    np.testing.assert_array_equal(
        np.asarray(out["x"], dtype=np.float32),
        np.asarray(x, dtype=np.float32),
    )
    assert out["x"].dtype == x.dtype
