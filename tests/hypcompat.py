"""Hypothesis compatibility shim.

The property tests use hypothesis when it is installed (see
``requirements-dev.txt``). When it is missing, importing it at module scope
used to kill collection of four whole test modules; this shim instead turns
only the ``@given`` property tests into skips so the plain unit tests in
those modules keep running.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade: property tests skip, everything else runs
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any ``st.<name>(...)`` call; values are never drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco
