"""Fault tolerance: injected node failures, checkpoint/restart, elastic remesh.

Reproduces the paper's §5.2 claim — 100 % completion — under conditions the
paper never tested: nodes dying mid-slice and restarts from disk.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.core import SimConfig
from repro.core.fault import FailureInjector, run_with_failures, revert_instances
from repro.core.sweep import SweepConfig, SweepRunner, completion_rate

SIM = SimConfig(n_slots=16)


def _cfg(**kw):
    base = dict(
        n_instances=8,
        steps_per_instance=120,
        chunk_steps=40,
        sim=SIM,
        seed=11,
    )
    base.update(kw)
    return SweepConfig(**base)


def test_failures_still_reach_full_completion():
    runner = SweepRunner(_cfg())
    injector = FailureInjector(n_workers=4, plan={0: [1], 1: [0, 3], 3: [2]})
    state, info = run_with_failures(runner, injector)
    assert info["completion_rate"] == 1.0
    assert len(info["failure_events"]) == 3
    # failures force extra chunks beyond the failure-free 3
    assert info["chunks_run"] > 3


def test_failed_run_metrics_match_clean_run():
    """Re-executed instances produce byte-identical results (determinism)."""
    clean = SweepRunner(_cfg()).run()
    runner = SweepRunner(_cfg())
    injector = FailureInjector(n_workers=4, plan={0: [0], 2: [1, 2]})
    state, info = run_with_failures(runner, injector)
    assert info["completion_rate"] == 1.0
    for a, b in zip(jax.tree.leaves(clean.metrics),
                    jax.tree.leaves(state.metrics)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_random_failure_storm_completes():
    runner = SweepRunner(_cfg(n_instances=6))
    injector = FailureInjector.random(
        n_workers=3, n_chunks=4, fail_prob=0.4, seed=5
    )
    state, info = run_with_failures(runner, injector, max_chunks=60)
    assert info["completion_rate"] == 1.0


def test_checkpoint_restart_resumes(tmp_path):
    cfg = _cfg()
    ckpt = CheckpointManager(str(tmp_path / "sweep"), async_write=False)
    runner = SweepRunner(cfg)

    # run only the first chunk, checkpointing
    state = runner.init()
    state = runner.run_chunk(state)
    ckpt.save(int(jax.device_get(state.chunk)), state)

    # "job killed" — fresh runner restores from disk and finishes
    runner2 = SweepRunner(cfg)
    injector = FailureInjector(n_workers=4, plan={})
    state2, info = run_with_failures(runner2, injector, ckpt=ckpt)
    assert info["completion_rate"] == 1.0

    # equal to a never-interrupted run
    clean = SweepRunner(cfg).run()
    for a, b in zip(jax.tree.leaves(clean.metrics),
                    jax.tree.leaves(state2.metrics)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


from conftest import assert_states_equal as _assert_states_equal

MIX = ("highway_merge", "lane_drop", "stop_and_go", "speed_limit_zone")


@pytest.mark.parametrize("compaction", [True, False])
def test_failure_parity_grouped_vs_switch(compaction):
    """Failure masks address LOGICAL instance ids, so the same injection
    plan kills the same instances under either dispatch mode and the full
    final states are bit-for-bit equal — the planner's physical repacking
    never leaks into fault semantics."""
    plan = {0: [0], 1: [2, 3], 3: [1]}
    finals = {}
    for dispatch in ("switch", "grouped"):
        runner = SweepRunner(_cfg(scenario_mix=MIX, compaction=compaction,
                                  dispatch=dispatch))
        injector = FailureInjector(n_workers=4, plan=dict(plan))
        finals[dispatch], info = run_with_failures(runner, injector)
        assert info["completion_rate"] == 1.0
        assert len(info["failure_events"]) == 3
    _assert_states_equal(finals["switch"], finals["grouped"])


@pytest.mark.parametrize("dispatch", ["switch", "grouped"])
def test_checkpoint_roundtrip_resume_parity(dispatch, tmp_path):
    """A mid-sweep SweepState survives a CheckpointManager round trip and
    the resumed run finishes bit-identical to a never-interrupted run, under
    both dispatch modes."""
    cfg = _cfg(scenario_mix=MIX, vary_horizon=True, min_horizon_frac=0.3,
               dispatch=dispatch)
    ckpt = CheckpointManager(str(tmp_path / "sw"), async_write=False)

    runner = SweepRunner(cfg)
    state = runner.init()
    state = runner.run_chunk(state)
    ckpt.save(int(jax.device_get(state.chunk)), state)

    # the restored tree is bit-identical to what was saved
    restored, meta = ckpt.restore(like=state)
    _assert_states_equal(state, restored)
    assert meta["step"] == 1

    # "job killed" — a fresh runner resumes from disk and finishes
    runner2 = SweepRunner(cfg)
    final, info = run_with_failures(
        runner2, FailureInjector(n_workers=4, plan={}), ckpt=ckpt
    )
    assert info["completion_rate"] == 1.0
    clean = SweepRunner(cfg).run()
    _assert_states_equal(clean, final)


def test_revert_instances_partial():
    runner = SweepRunner(_cfg())
    s0 = runner.init()
    s1 = runner.run_chunk(s0)
    mask = np.zeros(8, bool)
    mask[:4] = True
    reverted = revert_instances(s1, s0, mask)
    t = np.asarray(jax.device_get(reverted.sim.t))
    assert (t[:4] == 0).all()          # reverted to snapshot
    assert (t[4:] == 40).all()         # kept chunk progress


def test_elastic_remesh_noop_on_single_device():
    """Remesh keeps logical state intact (single-device degenerate case)."""
    def to_np(x):
        if jax.dtypes.issubdtype(x.dtype, jax.dtypes.prng_key):
            x = jax.random.key_data(x)
        return np.asarray(jax.device_get(x))

    runner = SweepRunner(_cfg())
    state = runner.init()
    state = runner.run_chunk(state)
    before = jax.tree.map(to_np, state)
    mesh = jax.make_mesh((1,), ("workers",))
    state2 = runner.remesh(state, mesh)
    after = jax.tree.map(to_np, state2)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(a, b)
    # and the sweep still completes on the new mesh
    final = runner.run(state2)
    assert completion_rate(final) == 1.0


# --------------------------------------------------------------------------
# trajectory recording under faults: the dispatch-agnostic, resume-exact
# dataset channel (repro.core.record)
# --------------------------------------------------------------------------

from repro.core.record import RecordConfig

REC = RecordConfig(record_every=10, k_slots=4)
MIX2 = ("highway_merge", "lane_drop")
_REC_KW = dict(n_instances=8, steps_per_instance=80, chunk_steps=40,
               sim=SIM, seed=11, scenario_mix=MIX2, record=REC)


@pytest.mark.parametrize("dispatch", ["switch", "grouped"])
def test_recording_parity_under_injected_failures(dispatch):
    """Node failures revert instances to their chunk snapshot; the re-run
    rewrites the SAME trace rows with identical values, so the final
    recorded dataset is bit-for-bit equal to a failure-free run — under
    both dispatch modes."""
    clean = SweepRunner(SweepConfig(**_REC_KW)).run()
    runner = SweepRunner(SweepConfig(dispatch=dispatch, **_REC_KW))
    injector = FailureInjector(n_workers=4, plan={0: [0], 1: [2, 3]})
    state, info = run_with_failures(runner, injector)
    assert info["completion_rate"] == 1.0
    assert len(info["failure_events"]) == 2
    # failures force extra walltime slices; everything else — trace
    # included — must match the clean run bitwise
    _assert_states_equal(clean, state._replace(chunk=clean.chunk))


@pytest.mark.parametrize("dispatch", ["switch", "grouped"])
def test_recording_checkpoint_kill_resume_parity(dispatch, tmp_path):
    """A mid-sweep kill/resume through CheckpointManager neither drops nor
    duplicates recorded rows: the resumed run's full state — trace buffer
    included — is bit-identical to a never-interrupted run."""
    cfg = SweepConfig(dispatch=dispatch, vary_horizon=True,
                      min_horizon_frac=0.3, **_REC_KW)
    ckpt = CheckpointManager(str(tmp_path / "sw"), async_write=False)

    runner = SweepRunner(cfg)
    state = runner.init()
    state = runner.run_chunk(state)
    ckpt.save(int(jax.device_get(state.chunk)), state)

    # the restored tree (trace included) is bit-identical to what was saved
    restored, meta = ckpt.restore(like=state)
    _assert_states_equal(state, restored)

    # "job killed" — a fresh runner resumes from disk and finishes
    final, info = run_with_failures(
        SweepRunner(cfg), FailureInjector(n_workers=4, plan={}), ckpt=ckpt
    )
    assert info["completion_rate"] == 1.0
    clean = SweepRunner(cfg).run()
    _assert_states_equal(clean, final)
