"""HLO collective parser: synthetic lines + a real lowered program."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils.hlo import collective_bytes


def test_explicit_groups_all_reduce():
    hlo = (
        "%ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), "
        "replica_groups={{0,1,2,3}}, to_apply=%sum"
    )
    st = collective_bytes(hlo)
    assert st.counts["all-reduce"] == 1
    payload = 128 * 256 * 4
    assert st.payload_bytes["all-reduce"] == payload
    np.testing.assert_allclose(
        st.wire_bytes["all-reduce"], 2 * payload * 3 / 4
    )


def test_iota_groups_all_gather():
    hlo = (
        "%ag = bf16[16,4096]{1,0} all-gather(bf16[1,4096]{1,0} %x), "
        "replica_groups=[32,16]<=[512], dimensions={0}"
    )
    st = collective_bytes(hlo)
    assert st.counts["all-gather"] == 1
    out_bytes = 16 * 4096 * 2
    np.testing.assert_allclose(
        st.wire_bytes["all-gather"], out_bytes * 15 / 16
    )


def test_reduce_scatter_uses_input_bytes():
    hlo = (
        "%rs = f32[8,128]{1,0} reduce-scatter(f32[64,128]{1,0} %x), "
        "replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}, to_apply=%sum"
    )
    st = collective_bytes(hlo)
    in_bytes = 64 * 128 * 4
    np.testing.assert_allclose(
        st.wire_bytes["reduce-scatter"], in_bytes * 7 / 8
    )


def test_collective_permute_full_buffer():
    hlo = (
        "%cp = bf16[1024]{0} collective-permute(bf16[1024]{0} %x), "
        "source_target_pairs={{0,1},{1,0}}"
    )
    st = collective_bytes(hlo)
    assert st.wire_bytes["collective-permute"] == 1024 * 2


def test_done_ops_not_double_counted():
    hlo = "\n".join([
        "%s = f32[256]{0} all-reduce-start(f32[256]{0} %x), "
        "replica_groups={{0,1}}, to_apply=%sum",
        "%d = f32[256]{0} all-reduce-done(f32[256]{0} %s)",
    ])
    st = collective_bytes(hlo)
    assert st.counts.get("all-reduce", 0) == 1


def test_non_collective_lines_ignored():
    st = collective_bytes(
        "%add = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)"
    )
    assert st.total_wire_bytes == 0.0


def test_real_lowered_program_has_allreduce():
    """psum under shard_map must surface in the parsed stats."""
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("x",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def f(a):
        return jax.lax.psum(a, "x")

    g = jax.jit(
        shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P())
    )
    hlo = g.lower(jnp.ones((8, 8))).compile().as_text()
    st = collective_bytes(hlo)
    assert st.counts.get("all-reduce", 0) >= 1
