"""Attention internals: tiled flash_xla vs plain sdpa, masks, MLA decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    sdpa,
    flash_xla,
    full_attention,
    causal_mask,
)


@pytest.mark.parametrize("causal,window", [
    (True, 0), (True, 64), (False, 0),
])
def test_flash_xla_matches_sdpa(causal, window):
    """The statically-tiled flash path must equal plain softmax attention."""
    b, s, h, kh, d = 2, 256, 4, 2, 32
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kh, d), jnp.float32)
    out = flash_xla(
        q, k, v, causal=causal, window=window, scale=d**-0.5,
        tile_q=64, tile_k=64,
    )
    mask = causal_mask(s, s, window) if causal else None
    ref = sdpa(q, k, v, mask, d**-0.5)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-4
    )


def test_flash_xla_softcap():
    b, s, h, d = 1, 128, 2, 16
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    out = flash_xla(q, k, v, causal=True, window=0, scale=d**-0.5,
                    cap=30.0, tile_q=32, tile_k=32)
    ref = sdpa(q, k, v, causal_mask(s, s), d**-0.5, 30.0)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-4
    )


def test_full_attention_dispatches_small_seq_exactly():
    b, s, h, d = 1, 64, 2, 16
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    out = full_attention(q, k, v, causal=True, window=0, scale=d**-0.5)
    ref = sdpa(q, k, v, causal_mask(s, s), d**-0.5)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_flash_xla_window_skips_are_correct():
    """Window smaller than a tile: early tiles fully outside must not
    contribute (exercises the static skip logic)."""
    b, s, h, d = 1, 512, 1, 16
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    out = flash_xla(q, k, v, causal=True, window=32, scale=d**-0.5,
                    tile_q=128, tile_k=128)
    ref = sdpa(q, k, v, causal_mask(s, s, 32), d**-0.5)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-4
    )


def test_flash_xla_grad_finite():
    """The tiled path must be differentiable (training uses it at 4k)."""
    b, s, h, d = 1, 128, 2, 16
    ks = jax.random.split(jax.random.key(4), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)

    def f(q):
        return flash_xla(q, k, v, causal=True, window=0, scale=d**-0.5,
                         tile_q=32, tile_k=32).sum()

    g = jax.grad(f)(q)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).max()) > 0
