"""Shared test helpers (importable as ``from conftest import ...``)."""

import jax
import numpy as np


def state_tree_np(state):
    """Whole pytree as numpy (PRNG keys unwrapped) for bit-for-bit diffs."""
    def to_np(x):
        if hasattr(x, "dtype") and jax.dtypes.issubdtype(
            x.dtype, jax.dtypes.prng_key
        ):
            x = jax.random.key_data(x)
        return np.asarray(jax.device_get(x))

    return jax.tree.map(to_np, state)


def assert_states_equal(a, b):
    """Bit-for-bit equality of two pytrees with identical structure."""
    la = jax.tree.leaves(state_tree_np(a))
    lb = jax.tree.leaves(state_tree_np(b))
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(x, y)
