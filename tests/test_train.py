"""Training substrate: optimizer, schedules, microbatching, trainer restart."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypcompat import given, settings, st

from repro.config import TrainConfig, get_arch
from repro.models import build_model
from repro.train import (
    adamw_init,
    adamw_update,
    lr_schedule,
    make_train_step,
    cross_entropy_loss,
)
from repro.train.optim import (
    clip_by_global_norm,
    global_norm,
    compress_int8,
    decompress_int8,
    compressed_grads_with_feedback,
)
from repro.train.trainer import Trainer
from repro.data import synthetic_batches


def _tiny_model():
    return build_model(get_arch("qwen1.5-0.5b").reduced(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=64,
    ))


def test_adamw_converges_on_quadratic():
    tc = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=200,
                     schedule="constant", weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2.0 * params["w"]}  # d/dw of w²
        params, state, _ = adamw_update(grads, state, params, tc)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_lr_schedule_shapes():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(tc, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1e-3) < 1e-9          # warmup peak
    assert lrs[100] < 1e-5                      # cosine decayed
    assert all(b <= a + 1e-12 for a, b in zip(lrs[10:], lrs[11:]))  # monotone


def test_grad_clip():
    g = {"a": jnp.ones((4,)) * 100.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) == pytest.approx(200.0)


def test_cross_entropy_perfect_prediction():
    logits = jnp.full((1, 4, 8), -30.0).at[0, :, 2].set(30.0)
    labels = jnp.full((1, 4), 2, jnp.int32)
    loss, _ = cross_entropy_loss(logits, labels)
    assert float(loss) < 1e-4


def test_microbatching_matches_full_batch():
    """Gradient accumulation must be semantically identical to full batch."""
    model = _tiny_model()
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (4, 16), 0, 64)
    batch = {"tokens": tokens[:, :-1].repeat(1, 0),
             "labels": tokens[:, 1:]}
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}

    tc1 = TrainConfig(microbatches=1, warmup_steps=0, schedule="constant")
    tc4 = TrainConfig(microbatches=4, warmup_steps=0, schedule="constant")
    s1 = adamw_init(params)
    s4 = adamw_init(params)
    p1, _, m1 = jax.jit(make_train_step(model, tc1))(params, s1, batch)
    p4, _, m4 = jax.jit(make_train_step(model, tc4))(params, s4, batch)
    np.testing.assert_allclose(
        float(m1["loss"]), float(m4["loss"]), rtol=1e-5
    )
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-2, atol=2e-3,
        )


def test_trainer_loss_decreases_and_restarts(tmp_path):
    model = _tiny_model()
    tc = TrainConfig(
        learning_rate=3e-3, warmup_steps=5, total_steps=30,
        schedule="cosine", seed=0,
    )
    data = synthetic_batches(model.cfg, batch=4, seq=16, seed=1)
    tr = Trainer(model, tc, data, ckpt_dir=str(tmp_path / "ck"),
                 ckpt_every=10, log_every=10, log_fn=lambda s: None)
    tr.run(steps=20)
    losses = [h["loss"] for h in tr.history]
    assert losses[-1] < losses[0]

    # restart resumes from step 20 (fresh data iterator seeks via history)
    data2 = synthetic_batches(model.cfg, batch=4, seq=16, seed=1,
                              start_step=20)
    tr2 = Trainer(model, tc, data2, ckpt_dir=str(tmp_path / "ck"),
                  ckpt_every=10, log_every=10, log_fn=lambda s: None)
    params2, _ = tr2.run(steps=30)
    assert tr2.history[0]["step"] == 30


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(1e-4, 1e3))
def test_property_int8_compression_bounded_error(scale):
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64,)) * scale,
                    jnp.float32)
    q, s = compress_int8(g)
    err = jnp.abs(decompress_int8(q, s) - g).max()
    assert float(err) <= float(s) * 0.5 + 1e-9


def test_error_feedback_accumulates():
    """With error feedback, the mean of compressed grads tracks the truth."""
    rng = np.random.default_rng(1)
    g_true = {"w": jnp.asarray(rng.normal(size=(32,)) * 1e-3, jnp.float32)}
    err = {"w": jnp.zeros((32,), jnp.float32)}
    acc = jnp.zeros((32,), jnp.float32)
    for _ in range(64):
        sent, err = compressed_grads_with_feedback(g_true, err)
        acc = acc + sent["w"].astype(jnp.float32)
    mean_sent = acc / 64.0
    np.testing.assert_allclose(
        np.asarray(mean_sent), np.asarray(g_true["w"]), atol=5e-5
    )
