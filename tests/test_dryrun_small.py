"""Dry-run machinery at reduced scale (subprocess: needs its own device
count flag before jax init). Covers lower+compile with shardings, the
costing extrapolation, and the roofline artifact schema for one arch of
each family kind."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, dataclasses
import jax
from repro.config import get_arch, SHAPES, TrainConfig
from repro.launch.costing import extrapolated_costs
from repro.launch.roofline import roofline_report, model_flops, param_counts
from repro.launch.specs import input_specs, cell_is_applicable
from repro.models import build_model
from repro.sharding import param_shardings, batch_shardings

mesh = jax.make_mesh((2, 4), ("data", "model"))

arch = "__ARCH__"
shape_name = "__SHAPE__"
cfg = get_arch(arch).reduced(
    d_model=64, n_heads=4, n_kv_heads=4 if get_arch(arch).n_kv_heads > 1 else 1,
    head_dim=16, d_ff=128, vocab_size=512,
)
shape = dataclasses.replace(
    SHAPES[shape_name], global_batch=8, seq_len=256
)
tc = TrainConfig(remat="full", microbatches=2)

model = build_model(cfg)
ext = extrapolated_costs(cfg, shape, mesh, tc if shape.kind == "train" else None)
assert ext["flops_per_device"] > 0
assert ext["bytes_per_device"] > 0
rep = roofline_report(
    flops_per_device=ext["flops_per_device"],
    bytes_per_device=ext["bytes_per_device"],
    wire_bytes_per_device=ext["wire_bytes_per_device"],
    n_devices=8,
    model_flops_global=model_flops(cfg, shape),
)
assert rep["dominant"] in ("compute", "memory", "collective")
print(json.dumps({"ok": True, "dominant": rep["dominant"],
                  "flops": ext["flops_per_device"]}))
"""


def _run(arch: str, shape: str):
    code = SCRIPT.replace("__ARCH__", arch).replace("__SHAPE__", shape)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=REPO, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("qwen1.5-0.5b", "train_4k"),      # dense train
    ("olmoe-1b-7b", "decode_32k"),     # MoE decode
    ("recurrentgemma-2b", "prefill_32k"),  # hybrid prefill
])
def test_reduced_dryrun_cell(arch, shape):
    res = _run(arch, shape)
    assert res["ok"] and res["flops"] > 0
