"""Recording subsystem + sharded Phase-III dataset pipeline tests.

Covers the pieces between the sweep engine and LM training: TraceBuffer
semantics, trace → token-stream serialization, the streaming DatasetWriter
(shard layout, manifest, fault-safe drain, kill/resume idempotency) and the
shard-backed training corpus.
"""

import os

import jax
import numpy as np
import pytest

from repro.config import get_arch
from repro.core import SimConfig
from repro.core.aggregate import aggregate_metrics
from repro.core.fault import FailureInjector, run_with_failures
from repro.core.record import RecordConfig, TraceBuffer, batch_zeros
from repro.core.sweep import SweepConfig, SweepRunner
from repro.core.tokens import (
    BOS,
    EOS,
    PAD,
    Trajectory,
    trace_token_streams,
    trajectory_to_tokens,
    vocab_size,
)
from repro.data import sim_token_batches
from repro.data.shards import DatasetWriter, ShardedDataset, write_dataset

SIM = SimConfig(n_slots=16)
REC = RecordConfig(record_every=10, k_slots=4)


def _cfg(**kw):
    base = dict(
        n_instances=6,
        steps_per_instance=60,
        chunk_steps=30,
        sim=SIM,
        seed=5,
        scenario_mix=("highway_merge", "lane_drop"),
        record=REC,
    )
    base.update(kw)
    return SweepConfig(**base)


_STATE_CACHE: dict = {}


def _run(**kw):
    key = tuple(sorted(kw.items()))
    if key not in _STATE_CACHE:
        _STATE_CACHE[key] = SweepRunner(_cfg(**kw)).run()
    return _STATE_CACHE[key]


# ---------------------------------------------------------------- buffers

def test_trace_buffer_shapes_and_batch_zeros():
    tb = TraceBuffer.zeros(REC, 60)
    assert tb.series.shape == (6, len(REC.fields))
    assert tb.lane.shape == tb.speed.shape == tb.active.shape == (6, 4)
    stacked = batch_zeros(REC, 60, 3)
    assert stacked.series.shape == (3, 6, len(REC.fields))
    assert stacked.lane.dtype == np.int32 and stacked.active.dtype == bool


def test_series_counters_are_cumulative_and_consistent():
    """Counter channels record the cumulative value at the sampled step, so
    the last row equals the terminal SimMetrics and rows are monotone."""
    state = _run()
    tr = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state.trace)
    fields = list(REC.fields)
    tp = tr.series[:, :, fields.index("throughput")]
    lc = tr.series[:, :, fields.index("lane_changes")]
    assert (np.diff(tp, axis=1) >= 0).all() and (np.diff(lc, axis=1) >= 0).all()
    m = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state.metrics)
    # horizon 60 is a multiple of the stride: row -1 is the terminal state
    np.testing.assert_array_equal(tp[:, -1], m.throughput.astype(np.float32))
    np.testing.assert_array_equal(lc[:, -1], m.lane_changes.astype(np.float32))


# ------------------------------------------------------------ token streams

def test_trace_token_streams_matches_trajectory_to_tokens():
    """Full-horizon streams reproduce the original serializer bit-for-bit
    (same frame code), modulo the fixed-shape PAD tail."""
    state = _run()
    tr = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state.trace)
    valid = np.full(tr.lane.shape[0], tr.lane.shape[1])
    streams, lengths = trace_token_streams(
        tr.lane, tr.speed, tr.active, valid, SIM
    )
    for i in range(tr.lane.shape[0]):
        ref = np.asarray(trajectory_to_tokens(
            Trajectory(tr.lane[i], tr.speed[i], tr.active[i]), SIM
        ))
        assert lengths[i] == ref.shape[0]
        np.testing.assert_array_equal(streams[i], ref)


def test_trace_token_streams_variable_horizons():
    lane = np.zeros((3, 5, 2), np.int32)
    speed = np.full((3, 5, 2), 20.0, np.float32)
    active = np.ones((3, 5, 2), bool)
    valid = np.array([5, 2, 0])
    streams, lengths = trace_token_streams(lane, speed, active, valid, SIM)
    fw = 3  # 2 vehicle tokens + SEP
    np.testing.assert_array_equal(lengths, 2 + valid * fw)
    for s, n in zip(streams, lengths):
        assert s[0] == BOS and s[n - 1] == EOS
        assert (s[n:] == PAD).all()
        assert (s[1:n - 1] >= 4).sum() == (n - 2) * 2 // 3  # vehicle tokens
    assert (streams[2][1:] == [EOS] + [PAD] * (streams.shape[1] - 2)).all()
    assert (streams < vocab_size(SIM)).all()


# ------------------------------------------------------- writer + reader

def test_dataset_writer_streams_shards_and_manifest(tmp_path):
    root = str(tmp_path / "ds")
    cfg = _cfg(vary_horizon=True, min_horizon_frac=0.3)
    runner = SweepRunner(cfg)
    writer = DatasetWriter(root, cfg, shard_size=2)
    state, info = run_with_failures(
        runner, FailureInjector(n_workers=4, plan={0: [1]}), writer=writer
    )
    summary = aggregate_metrics(state.metrics, state.scenario_id,
                                cfg.scenarios)
    manifest_path = writer.finalize(summary=summary, fault_info=info)
    assert os.path.exists(manifest_path)

    ds = ShardedDataset.load(root)
    assert ds.n_instances == cfg.n_instances
    man = ds.manifest
    assert man["format"].startswith("webots-hpc-phase3")
    assert man["scenarios"] == list(cfg.scenarios)
    assert man["record"]["record_every"] == REC.record_every
    assert man["metric_aliases"]["lane_drop"]  # aliases shipped for readers
    assert man["fault_events"] == info["failure_events"]
    assert sum(s["n_instances"] for s in man["shards"]) == cfg.n_instances

    # each logical instance lands in exactly one shard
    all_ids = [i for s in man["shards"] for i in s["instances"]]
    assert sorted(all_ids) == list(range(cfg.n_instances))

    recs = ds.records()
    assert sorted(r["instance"] for r in recs) == list(range(cfg.n_instances))
    by_id = {r["instance"]: r for r in recs}
    assert "forced_merges" in by_id[1]  # lane_drop aliases in jsonl records

    fields, series, valid = ds.series()
    assert fields == list(REC.fields)
    assert series.shape[0] == cfg.n_instances
    h = np.asarray(jax.device_get(state.horizon))
    np.testing.assert_array_equal(
        np.sort(valid), np.sort(h // REC.record_every)
    )
    streams, lengths = ds.token_streams()
    assert (streams[:, 0] == BOS).all()
    corpus = ds.token_corpus()
    assert corpus.shape[0] == lengths.sum() and (corpus != PAD).all()


def test_dataset_matches_in_memory_state(tmp_path):
    """Shards are a faithful serialization: series/tokens re-loaded from
    disk equal the in-memory trace for every logical instance."""
    root = str(tmp_path / "ds")
    cfg = _cfg()
    state = SweepRunner(cfg).run()
    write_dataset(root, state, cfg, shard_size=4)
    ds = ShardedDataset.load(root)
    tr = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state.trace)
    order = np.argsort(np.concatenate(
        [s["instances"] for s in ds.manifest["shards"]]
    ))
    _, series, _ = ds.series()
    np.testing.assert_array_equal(series[order], tr.series)


def test_dataset_writer_kill_resume_never_drops_or_duplicates(tmp_path):
    """Writer torn down mid-sweep ("job killed"), a fresh writer resumes on
    the same directory: every instance appears exactly once and the shard
    payloads equal an uninterrupted run's."""
    root = str(tmp_path / "ds")
    cfg = _cfg(vary_horizon=True, min_horizon_frac=0.3)

    # partial run: two chunks' worth of drains, then the process "dies"
    runner = SweepRunner(cfg)
    w1 = DatasetWriter(root, cfg, shard_size=2)
    state = runner.init()
    for _ in range(2):
        state = runner.run_chunk(state)
        w1.drain(state)
    persisted_early = set(w1.written)  # full shards already on disk
    del w1  # buffered-but-unflushed instances are lost with the process

    # resume: a fresh writer re-scans the directory, the sweep re-runs
    w2 = DatasetWriter(root, cfg, shard_size=2)
    assert w2.written == persisted_early
    final, info = run_with_failures(
        SweepRunner(cfg), FailureInjector(n_workers=4, plan={}),
        state=runner.init(), writer=w2,
    )
    assert info["completion_rate"] == 1.0
    w2.finalize()

    ds = ShardedDataset.load(root)
    all_ids = [i for s in ds.manifest["shards"] for i in s["instances"]]
    assert sorted(all_ids) == list(range(cfg.n_instances))  # no drop/dup

    # payload parity with a one-shot uninterrupted write
    clean_root = str(tmp_path / "clean")
    write_dataset(clean_root, SweepRunner(cfg).run(), cfg, shard_size=2)
    clean = ShardedDataset.load(clean_root)

    def by_instance(d):
        out = {}
        for z in d.iter_shards():
            for j, i in enumerate(z["instance"]):
                out[int(i)] = {k: v[j] for k, v in z.items()}
        return out

    a, b = by_instance(ds), by_instance(clean)
    assert a.keys() == b.keys()
    for i in a:
        for k in a[i]:
            np.testing.assert_array_equal(a[i][k], b[i][k], err_msg=f"{i}/{k}")


def test_writer_requires_recording_config(tmp_path):
    with pytest.raises(ValueError):
        DatasetWriter(str(tmp_path), _cfg(record=None))
    with pytest.raises(ValueError):
        DatasetWriter(str(tmp_path), _cfg(), shard_size=0)


# ------------------------------------------------------- training bridge

def test_sim_token_batches_from_shards(tmp_path):
    """sweep → shards → sim_token_batches: the LM trains on genuine sweep
    output, and the shard-backed corpus equals the shard token corpus."""
    root = str(tmp_path / "ds")
    cfg = _cfg()
    state = SweepRunner(cfg).run()
    write_dataset(root, state, cfg, shard_size=3)

    model_cfg = get_arch("qwen1.5-0.5b").reduced(vocab_size=256)
    it = sim_token_batches(model_cfg, SIM, batch=2, seq=16, shard_dir=root)
    b = next(it)
    assert b["tokens"].shape == (2, 16)
    corpus = ShardedDataset.load(root).token_corpus()
    span = 2 * 17
    np.testing.assert_array_equal(
        np.asarray(b["tokens"]).reshape(-1),
        corpus[:span].reshape(2, 17)[:, :-1].reshape(-1),
    )


def test_writer_drains_on_resume_of_finished_sweep(tmp_path):
    """Resuming a checkpoint whose sweep is already 100% done (or killed
    between the final ckpt.save and its drain) must still write every
    instance: run_with_failures drains once more after the loop breaks."""
    from repro.ckpt import CheckpointManager

    root = str(tmp_path / "ds")
    cfg = _cfg()
    ckpt = CheckpointManager(str(tmp_path / "ck"), async_write=False)

    # finish the whole sweep WITH checkpoints but NO writer (the lost drain)
    _, info = run_with_failures(SweepRunner(cfg),
                                FailureInjector(n_workers=4, plan={}),
                                ckpt=ckpt)
    assert info["completion_rate"] == 1.0

    # resume the finished checkpoint with a writer: zero chunks run, yet
    # the dataset must still cover every instance
    w = DatasetWriter(root, cfg, shard_size=4)
    _, info2 = run_with_failures(SweepRunner(cfg),
                                 FailureInjector(n_workers=4, plan={}),
                                 ckpt=ckpt, writer=w)
    assert info2["chunks_run"] == 0
    w.finalize()
    ds = ShardedDataset.load(root)
    assert ds.n_instances == cfg.n_instances


def test_shard_backed_batches_validate_manifest_vocab(tmp_path):
    """The model-vocab check uses the manifest's stored vocab, not the
    caller's SimConfig: shards written with more buckets than the default
    must be rejected when the model vocab only covers the default."""
    root = str(tmp_path / "ds")
    cfg = _cfg()
    state = SweepRunner(cfg).run()
    write_dataset(root, state, cfg, shard_size=4, n_buckets=64)
    need = ShardedDataset.load(root).manifest["vocab_size"]
    model_cfg = get_arch("qwen1.5-0.5b").reduced(vocab_size=need - 1)
    with pytest.raises(AssertionError):
        next(sim_token_batches(model_cfg, SIM, batch=1, seq=8,
                               shard_dir=root))


def test_writer_resume_ignores_torn_temp_files(tmp_path):
    """A kill mid-shard-write leaves temp files; writer construction must
    skip them (and any non-numeric shard-lookalike) instead of crashing,
    and the resumed run must still produce a complete dataset."""
    root = str(tmp_path / "ds")
    os.makedirs(root)
    for junk in (".tmp_shard_00000.npz", "shard_00001.npz.tmp.npz"):
        with open(os.path.join(root, junk), "wb") as f:
            f.write(b"torn write")
    with open(os.path.join(root, ".tmp_records_00000.jsonl"), "w") as f:
        f.write("{\"torn\":")
    cfg = _cfg()
    w = DatasetWriter(root, cfg, shard_size=4)
    assert w.written == set()
    w.drain(_run())
    w.finalize()
    ds = ShardedDataset.load(root)
    assert ds.n_instances == cfg.n_instances
    assert sorted(r["instance"] for r in ds.records()) == list(range(6))


def test_writer_rescan_detects_truncated_shard(tmp_path):
    """A committed shard truncated after the fact (torn non-atomic fs, bit
    rot) is caught at writer construction: its files are removed, its
    instances forgotten, and the resumed run re-drains them — never a
    silently broken dataset."""
    root = str(tmp_path / "ds")
    cfg = _cfg()
    w = DatasetWriter(root, cfg, shard_size=2)
    w.drain(_run())
    assert len(w.written) == cfg.n_instances

    victim = os.path.join(root, "shard_00001.npz")
    with open(victim, "r+b") as f:
        f.truncate(os.path.getsize(victim) // 2)

    w2 = DatasetWriter(root, cfg, shard_size=2)
    assert w2.repaired == [1]
    assert not os.path.exists(victim)
    assert len(w2.written) == cfg.n_instances - 2
    w2.drain(_run())  # idempotent re-drain rewrites only the lost two
    w2.finalize()
    ds = ShardedDataset.load(root)
    assert ds.n_instances == cfg.n_instances
    assert ds.manifest["repaired_shards"] == [1]
    assert sorted(r["instance"] for r in ds.records()) == list(range(6))


def test_writer_verify_shards_repairs_in_flight(tmp_path):
    """verify_shards: the mid-run audit detects a shard corrupted AFTER
    commit, drops it, and reports the indices so the supervisor can
    journal the repair and re-drain."""
    root = str(tmp_path / "ds")
    cfg = _cfg()
    w = DatasetWriter(root, cfg, shard_size=2)
    w.drain(_run())
    assert w.verify_shards() == []  # intact: audit is a no-op

    victim = os.path.join(root, "shard_00000.npz")
    with open(victim, "r+b") as f:
        f.truncate(3)
    assert w.verify_shards() == [0]
    assert not os.path.exists(victim)
    w.drain(_run())
    w.finalize()
    ds = ShardedDataset.load(root)
    assert ds.n_instances == cfg.n_instances
    assert ds.manifest["repaired_shards"] == [0]
