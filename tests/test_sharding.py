"""Sharding rules: every param/cache leaf of every arch gets a valid spec."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import get_arch, SHAPES
from repro.configs import ALL_ARCHS
from repro.models import build_model
from repro.sharding import (
    param_specs,
    batch_specs,
    cache_specs,
    dp_axes,
    tp_axis,
)
from repro.utils.tree import tree_flatten_with_paths


def _mesh(shape=(2, 2), axes=("data", "model")):
    # single-device "mesh" stand-in isn't enough to validate divisibility,
    # so build an abstract mesh over the same device repeated logically.
    from repro.launch.mesh import make_abstract_mesh

    return make_abstract_mesh(shape, axes)


def _flatten_specs(specs):
    flat, _ = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
        out.append(("/".join(parts), leaf))
    return out


def _axis_size(mesh, entry):
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        s = 1
        for a in entry:
            s *= mesh.shape[a]
        return s
    return mesh.shape[entry]


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.parametrize(
    "mesh_shape,axes",
    [((16, 16), ("data", "model")), ((2, 16, 16), ("pod", "data", "model"))],
)
def test_param_specs_divide_full_configs(arch, mesh_shape, axes):
    """FULL-size configs: abstract init only (no allocation), every spec
    entry must evenly divide its dim and use each mesh axis at most once."""
    cfg = get_arch(arch)
    model = build_model(cfg)
    abstract = model.abstract_params()
    mesh = _mesh(mesh_shape, axes)
    specs = param_specs(cfg, abstract, mesh)
    named_leaves = tree_flatten_with_paths(abstract)
    named_specs = _flatten_specs(specs)
    assert len(named_leaves) == len(named_specs)
    n_sharded = 0
    for (path, leaf), (_, spec) in zip(named_leaves, named_specs):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        used = []
        for dim, entry in zip(leaf.shape, spec):
            size = _axis_size(mesh, entry)
            assert dim % size == 0, (path, spec, leaf.shape)
            if entry is not None:
                used.extend(entry if isinstance(entry, tuple) else [entry])
                n_sharded += 1
        assert len(used) == len(set(used)), (path, spec)
    # the big weights must actually be sharded, not silently replicated
    assert n_sharded > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_large_weights_are_sharded(arch):
    """No ≥8M-element weight may be fully replicated on the 16x16 mesh."""
    cfg = get_arch(arch)
    model = build_model(cfg)
    abstract = model.abstract_params()
    mesh = _mesh((16, 16), ("data", "model"))
    specs = param_specs(cfg, abstract, mesh)
    named_leaves = tree_flatten_with_paths(abstract)
    named_specs = _flatten_specs(specs)
    for (path, leaf), (_, spec) in zip(named_leaves, named_specs):
        shape = leaf.shape
        # per-layer size is what matters: drop the stacked (scan) axis
        if path.split("/")[0] in ("blocks", "enc_blocks", "dec_blocks"):
            shape = shape[1:]
        n = int(np.prod(shape))
        if n >= 8_000_000:
            shards = 1
            for entry in spec:
                shards *= _axis_size(mesh, entry)
            assert shards > 1, f"{path} ({n} elems/layer) replicated: {spec}"


@pytest.mark.parametrize("arch", ["gemma2-9b", "deepseek-v2-236b", "rwkv6-3b",
                                  "recurrentgemma-2b", "whisper-large-v3"])
def test_cache_specs_divide(arch):
    cfg = get_arch(arch)
    model = build_model(cfg)
    batch = 8
    abstract = model.abstract_cache(batch, 4096)
    mesh = _mesh((16, 16), ("data", "model"))
    specs = cache_specs(mesh, abstract, batch)
    for (path, leaf), (_, spec) in zip(
        tree_flatten_with_paths(abstract), _flatten_specs(specs)
    ):
        for dim, entry in zip(leaf.shape, spec):
            assert dim % _axis_size(mesh, entry) == 0, (path, spec, leaf.shape)


def test_batch_specs():
    mesh = _mesh((2, 16, 16), ("pod", "data", "model"))
    abstract = {
        "tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32),
        "labels": jax.ShapeDtypeStruct((256, 128), jnp.int32),
        "mrope_pos": jax.ShapeDtypeStruct((3, 256, 128), jnp.int32),
    }
    specs = batch_specs(mesh, abstract)
    assert specs["tokens"] == P(("pod", "data"), None)
    assert specs["mrope_pos"] == P(None, ("pod", "data"), None)


def test_dp_tp_helpers():
    mesh = _mesh((2, 16, 16), ("pod", "data", "model"))
    assert dp_axes(mesh) == ("pod", "data")
    assert tp_axis(mesh) == "model"
