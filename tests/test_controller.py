"""Process controller: heartbeat supervision, SIGKILL/resume, chaos CI.

Fast tests drive ``_supervise_once`` with cheap jax-free child processes;
the end-to-end controller-over-real-sweep runs (multiple worker spawns,
each paying jax startup) are marked slow and exercised by the chaos CI
job.
"""

import json
import os
import subprocess
import sys
import time

import pytest

from repro.launch.controller import (
    _append_journal,
    _read_heartbeat,
    _supervise_once,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return env


def test_read_heartbeat_absent_and_torn(tmp_path):
    path = str(tmp_path / "hb.json")
    assert _read_heartbeat(path) is None
    with open(path, "w") as f:
        f.write('{"chunk": 3, "done"')  # torn write (non-atomic writer)
    assert _read_heartbeat(path) is None
    with open(path, "w") as f:
        json.dump({"chunk": 3, "done": 0.5, "time": 1.0}, f)
    assert _read_heartbeat(path)["chunk"] == 3


def test_supervise_kills_hung_worker(tmp_path):
    """A worker that never heartbeats is SIGKILLed once the timeout
    elapses, and the miss is journaled."""
    journal = str(tmp_path / "ctl.jsonl")
    proc = subprocess.Popen([sys.executable, "-c", "import time; time.sleep(60)"])
    t0 = time.monotonic()
    rc, reason = _supervise_once(
        proc, str(tmp_path / "hb.json"),
        timeout=1.0, poll=0.05, chaos_left=0, chaos_min_chunks=1,
        journal=journal,
    )
    assert reason == "hang"
    assert rc != 0
    assert time.monotonic() - t0 < 30
    events = [json.loads(l) for l in open(journal)]
    assert [e["kind"] for e in events] == ["heartbeat_miss"]
    assert events[0]["source"] == "controller"


def test_supervise_chaos_kill_after_progress(tmp_path):
    """Chaos mode SIGKILLs the worker only after it has committed the
    configured number of chunks since spawn."""
    hb = str(tmp_path / "hb.json")
    journal = str(tmp_path / "ctl.jsonl")
    script = (
        "import json, sys, time\n"
        "for c in range(100):\n"
        "    json.dump({'chunk': c, 'done': c/100, 'time': time.time()},"
        " open(sys.argv[1], 'w'))\n"
        "    time.sleep(0.05)\n"
    )
    proc = subprocess.Popen([sys.executable, "-c", script, hb])
    rc, reason = _supervise_once(
        proc, hb,
        timeout=30.0, poll=0.05, chaos_left=1, chaos_min_chunks=3,
        journal=journal,
    )
    assert reason == "chaos"
    assert rc != 0
    events = [json.loads(l) for l in open(journal)]
    assert events[0]["kind"] == "worker_kill"
    # chunk index 2 in the beacon = 3 committed chunks since spawn
    assert events[0]["chunk"] >= 2


def test_supervise_clean_exit(tmp_path):
    proc = subprocess.Popen([sys.executable, "-c", "pass"])
    rc, reason = _supervise_once(
        proc, str(tmp_path / "hb.json"),
        timeout=30.0, poll=0.05, chaos_left=0, chaos_min_chunks=1,
        journal=str(tmp_path / "ctl.jsonl"),
    )
    assert (rc, reason) == (0, "exit")


def test_append_journal_is_readable(tmp_path):
    path = str(tmp_path / "sub" / "ctl.jsonl")
    _append_journal(path, {"kind": "spawn", "attempt": 1})
    _append_journal(path, {"kind": "complete"})
    events = [json.loads(l) for l in open(path)]
    assert [e["kind"] for e in events] == ["spawn", "complete"]


def _run_controller(tmp_path, *extra, worker=()):
    cmd = [
        sys.executable, "-m", "repro.launch.controller",
        "--ckpt-dir", str(tmp_path / "run"),
        "--heartbeat-timeout", "300", "--poll", "0.2", *extra,
        "--",
        "--instances", "8", "--steps", "80", "--chunk-steps", "20",
        "--scenario-mix", "highway_merge,lane_drop", "--no-pipeline",
        *worker,
    ]
    return subprocess.run(
        cmd, env=_env(), capture_output=True, text=True, timeout=900
    )


@pytest.mark.slow
def test_controller_survives_two_sigkills_end_to_end(tmp_path):
    """The §5.2 acceptance smoke: two real SIGKILLs mid-run, unattended
    resume from the last valid checkpoint, 100 % completion reported."""
    res = _run_controller(
        tmp_path, "--chaos-kills", "2",
        worker=("--fail-prob", "0.05", "--seed", "3"),
    )
    assert res.returncode == 0, res.stderr
    ctl = [json.loads(l)
           for l in open(tmp_path / "run" / "controller.jsonl")]
    kinds = [e["kind"] for e in ctl]
    assert kinds.count("worker_kill") == 2
    assert kinds.count("spawn") == 3
    assert ctl[-1]["kind"] == "complete"
    assert ctl[-1]["eligible_completion_rate"] == 1.0
    assert ctl[-1]["completion_rate"] == 1.0
    # the worker's own journal shows the resumes
    worker = [json.loads(l)
              for l in open(tmp_path / "run" / "journal.jsonl")]
    assert sum(1 for e in worker if e["kind"] == "resume") == 2


@pytest.mark.slow
def test_controller_poison_quarantine_gate_passes(tmp_path):
    """A poison instance is quarantined and reported; eligible completion
    stays 100 % so the gate passes, and the quarantine is visible in the
    controller's output."""
    res = _run_controller(
        tmp_path, worker=("--poison", "3", "--max-retries", "2"),
    )
    assert res.returncode == 0, res.stderr
    ctl = [json.loads(l)
           for l in open(tmp_path / "run" / "controller.jsonl")]
    assert ctl[-1]["kind"] == "complete"
    assert ctl[-1]["quarantined"] == [3]
    assert ctl[-1]["eligible_completion_rate"] == 1.0
    assert ctl[-1]["completion_rate"] < 1.0
    assert "quarantined [3]" in res.stdout
