"""Data pipelines: seekability, learnable structure, sim-token batches."""

import jax
import numpy as np
import pytest

from repro.config import get_arch
from repro.core.scenario import SimConfig
from repro.data import sim_token_batches, synthetic_batches
from repro.core.tokens import vocab_size


def test_synthetic_seekable_restart():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    a = synthetic_batches(cfg, batch=2, seq=8, seed=3)
    first = [next(a) for _ in range(5)]
    b = synthetic_batches(cfg, batch=2, seq=8, seed=3, start_step=3)
    resumed = [next(b) for _ in range(2)]
    for x, y in zip(first[3:], resumed):
        np.testing.assert_array_equal(
            np.asarray(x["tokens"]), np.asarray(y["tokens"])
        )


def test_synthetic_walk_is_learnable_pattern():
    cfg = get_arch("qwen1.5-0.5b").reduced(vocab_size=64)
    batch = next(synthetic_batches(cfg, batch=2, seq=8))
    toks = np.asarray(batch["tokens"])
    labels = np.asarray(batch["labels"])
    np.testing.assert_array_equal((toks + 1) % 64, labels)


def test_synthetic_encdec_and_vlm_extras():
    whisper = get_arch("whisper-large-v3").reduced()
    b = next(synthetic_batches(whisper, batch=2, seq=8))
    assert b["frames"].shape == (2, whisper.enc_ctx, whisper.d_model)
    vlm = get_arch("qwen2-vl-2b").reduced()
    b = next(synthetic_batches(vlm, batch=2, seq=8))
    assert b["mrope_pos"].shape == (3, 2, 8)


def test_sim_token_batches_shapes_and_vocab():
    sim = SimConfig(n_slots=16)
    cfg = get_arch("qwen1.5-0.5b").reduced(vocab_size=256)
    it = sim_token_batches(cfg, sim, batch=2, seq=16, n_instances=2)
    b1 = next(it)
    b2 = next(it)
    assert b1["tokens"].shape == (2, 16)
    assert int(np.asarray(b1["tokens"]).max()) < vocab_size(sim)
    # labels are next-token shifted
    np.testing.assert_array_equal(
        np.asarray(b1["tokens"])[0, 1:], np.asarray(b1["labels"])[0, :-1]
    )
    # successive batches advance the corpus cursor
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b2["tokens"]))


def test_sim_vocab_too_small_raises():
    sim = SimConfig(n_slots=16)
    cfg = get_arch("qwen1.5-0.5b").reduced(vocab_size=8)
    with pytest.raises(AssertionError):
        next(sim_token_batches(cfg, sim, batch=1, seq=8, n_instances=1))
