"""Neighborhood engine parity: every implementation must match the
``neighbor_info`` oracle bit-for-bit, and ``sim_step`` must be trajectory-
identical across ``SimConfig.neighbor_impl`` settings."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SimConfig, init_state, sample_scenario_params
from repro.core.neighbors import (
    IMPLS,
    build_tables,
    neighbor_info,
    query_lanes,
)
from repro.core.simulator import sim_step

L = 4  # 3 main lanes + ramp
FIELDS = ("lead_idx", "lead_gap", "has_lead", "foll_idx", "foll_gap",
          "has_foll")


def _rand_world(key, n, p_act=0.8):
    """Random world with forced exact position ties (the argmin/stable-sort
    tie-break edge case) and inactive slots."""
    ks = jax.random.split(key, 3)
    pos = jax.random.uniform(ks[0], (n,), jnp.float32, 0.0, 900.0)
    lane = jax.random.randint(ks[1], (n,), 0, L)
    if n > 4:
        pos = pos.at[1].set(pos[0]).at[4].set(pos[0])
        lane = lane.at[1].set(lane[0]).at[4].set(lane[0])
    active = jax.random.uniform(ks[2], (n,)) < p_act
    return pos, lane, active


def _impl_kwargs(impl):
    return {"interpret": True} if impl == "pallas" else {}


@pytest.mark.parametrize("impl", [i for i in IMPLS if i != "reference"])
@pytest.mark.parametrize("n", [8, 16, 48, 200])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_tables_match_oracle_bitwise(impl, n, seed):
    pos, lane, active = _rand_world(jax.random.key(seed * 1000 + n), n)
    tabs = build_tables(pos, lane, active, 4.5, L, impl, **_impl_kwargs(impl))
    for l in range(L):
        q = jnp.full((n,), l, jnp.int32)
        ref = neighbor_info(pos, lane, active, 4.5, q)
        got = tabs.query(q)
        for name, a, b in zip(FIELDS, ref, got):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{impl} lane={l} field={name}",
            )


@pytest.mark.parametrize("impl", list(IMPLS))
@pytest.mark.parametrize("seed", [0, 1])
def test_query_lanes_match_oracle_bitwise(impl, seed):
    n = 64
    pos, lane, active = _rand_world(jax.random.key(seed + 77), n)
    qv = jax.random.randint(jax.random.key(seed + 123), (n,), 0, L)
    ref = neighbor_info(pos, lane, active, 4.5, qv)
    got = query_lanes(pos, lane, active, 4.5, qv, impl, n_lanes_total=L,
                      **_impl_kwargs(impl))
    for name, a, b in zip(FIELDS, ref, got):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"{impl} field={name}"
        )


def test_tables_on_live_simulator_states():
    """Parity on organically-evolved worlds (spawns, merges, exits)."""
    cfg = SimConfig(n_slots=32)
    sp = sample_scenario_params(jax.random.key(1), cfg)
    st = init_state(cfg, jax.random.key(0))
    step = jax.jit(lambda s: sim_step(s, cfg, sp))
    for _ in range(150):
        st, _ = step(st)
    nl = cfg.n_lanes + 1
    ref = build_tables(st.pos, st.lane, st.active, cfg.vehicle_len, nl,
                       "reference")
    for impl in ("dense", "sort", "pallas"):
        got = build_tables(st.pos, st.lane, st.active, cfg.vehicle_len, nl,
                           impl, **_impl_kwargs(impl))
        for name, a, b in zip(FIELDS, ref, got):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"{impl} {name}"
            )


def test_unknown_impl_raises():
    pos, lane, active = _rand_world(jax.random.key(0), 8)
    with pytest.raises(ValueError, match="neighbor_impl"):
        build_tables(pos, lane, active, 4.5, L, "quadtree")


@pytest.mark.parametrize("n_slots", [16, 48])
def test_sim_step_equivalent_across_impls(n_slots):
    """End-to-end: identical trajectories for every neighbor_impl."""
    base = SimConfig(n_slots=n_slots)
    sp = sample_scenario_params(jax.random.key(1), base)
    finals = {}
    for impl in IMPLS:
        cfg = dataclasses.replace(base, neighbor_impl=impl)
        st = init_state(cfg, jax.random.key(0))
        step = jax.jit(lambda s, cfg=cfg: sim_step(s, cfg, sp))
        for _ in range(100):
            st, _ = step(st)
        finals[impl] = jax.device_get(
            st._replace(key=jax.random.key_data(st.key))
        )
    ref = finals["reference"]
    for impl, st in finals.items():
        for name, a, b in zip(ref._fields, ref, st):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"{impl} {name}"
            )
    assert np.asarray(ref.active).sum() > 0  # the worlds actually populated
