"""Docs-system guardrails: the public surface must stay documented.

The ``docs`` satellite of the sharded-executor PR wrote docstrings (with
shapes/units) for every exported name in ``repro.core`` and the
``repro.data`` dataset surface, and pinned the semantics of
``RecordConfig``, ``SweepConfig.dispatch`` and ``GroupPlan`` in prose
instead of implying them through tests. This test keeps that from rotting:
an export added without a real docstring fails here, not in review.
"""

import inspect

MIN_DOC = 40  # characters: a one-liner is fine, an empty stub is not


def _assert_documented(obj, name, owner):
    doc = inspect.getdoc(obj)
    assert doc and len(doc) >= MIN_DOC, (
        f"{owner}.{name} is exported but has no meaningful docstring "
        f"(got {doc!r})"
    )


def test_core_public_surface_documented():
    import repro.core as core

    assert core.__doc__ and len(core.__doc__) > 100
    for name in core.__all__:
        _assert_documented(getattr(core, name), name, "repro.core")


def test_sweep_planner_semantics_documented():
    """The planner/executor vocabulary is written down, not implied."""
    from repro.core import record, sweep

    for obj in (
        sweep.SweepConfig,
        sweep.SweepState,
        sweep.GroupPlan,
        sweep.BlockPlan,
        sweep.plan_chunk,
        sweep.plan_chunk_blocks,
        sweep.instance_sharding,
        sweep.SweepRunner,
        sweep.SweepRunner.run_chunk,
        sweep.SweepRunner.run,
        sweep.SweepRunner.remesh,
        record.RecordConfig,
        record.TraceBuffer,
    ):
        _assert_documented(obj, obj.__name__, obj.__module__)
    # the dispatch contract lives on the config docstring + module doc
    assert "switch" in sweep.__doc__ and "grouped" in sweep.__doc__
    assert "LPT" in sweep.__doc__
    assert "dispatch" in inspect.getdoc(sweep.SweepConfig)
    assert "record_every" in inspect.getdoc(record.RecordConfig)


def test_data_public_surface_documented():
    from repro.data import shards, sim_dataset

    for obj in (
        shards.DatasetWriter,
        shards.DatasetWriter.begin_drain,
        shards.DatasetWriter.finish_drain,
        shards.DatasetWriter.drain,
        shards.DatasetWriter.finalize,
        shards.ShardedDataset,
        shards.write_dataset,
        sim_dataset.sim_token_batches,
        sim_dataset.sim_token_corpus,
    ):
        _assert_documented(obj, obj.__qualname__, obj.__module__)


def test_fault_and_mesh_documented():
    from repro.core import fault
    from repro.launch import mesh

    for obj in (
        fault.FailureInjector,
        fault.FailureInjector.instance_mask,
        fault.revert_instances,
        fault.run_with_failures,
        mesh.make_host_mesh,
        mesh.force_host_device_count,
        mesh.instance_sharding,
    ):
        _assert_documented(obj, obj.__qualname__, obj.__module__)
    # the sharding/dispatch-agnosticism guarantee is prose, not folklore
    assert "logical" in fault.__doc__.lower()
    assert "sharding" in fault.__doc__.lower()
