"""Bench-regression gate: diff a fresh BENCH_sweep.json against the baseline.

CI runs a quick-mode ``benchmarks.run --only sweep`` (reduced slots/steps)
and calls this script to compare it with the committed quick-mode baseline:

    python scripts/bench_gate.py BENCH_sweep_quick.json bench_fresh.json

CI compares like-with-like: the committed ``BENCH_sweep_quick.json`` was
recorded with ``SWEEP_BENCH_QUICK=1`` so the workload size matches the CI
run (the full-mode ``BENCH_sweep.json`` stays the PR-to-PR perf trajectory,
re-recorded on dev hardware when perf-relevant code changes).

Comparison rules (generous by design — CI-grade hardware is slower and
noisier than wherever the baseline was recorded):

- per scenario, the ``sort``-impl ``veh_steps_per_sec`` ratio vs baseline is
  first normalized by the median ratio across scenarios (dividing out
  uniform hardware speed differences, which are indistinguishable from a
  global slowdown on foreign hardware); a scenario whose NORMALIZED ratio
  regresses by more than ``--tolerance`` (default 40 %) FAILS the gate —
  that scenario got slower *relative to the others*, which no hardware
  difference explains. Raw-ratio dips past the tolerance only warn.
- the mixed-suite grouped-over-switch speedup on the largest mix must stay
  above ``--min-speedup`` (default 1.05) — grouped dispatch collapsing to
  switch-grade throughput means the planner is broken, and that holds on any
  hardware since both sides run on the same machine. The floor is deliberately
  just above 1.0: quick-mode + CI noise compresses the measured ratio well
  below the full-scale baseline (2.5x on the recording host), so larger dips
  (below ``WARN_SPEEDUP``) only warn.

A markdown summary is appended to ``$GITHUB_STEP_SUMMARY`` when set. Exit
code 1 = hard regression, 0 = clean or warn-only.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

IMPL = "sort"
METRIC = "veh_steps_per_sec"
WARN_FRAC = 0.15
WARN_SPEEDUP = 1.3
# Phase-III recording channel: the acceptance target is < 15 % step-rate
# cost at record_every=10; warn past that, hard-fail only past 30 % (CI
# noise headroom — both sides of the ratio run on the same machine)
WARN_RECORD_OVERHEAD = 0.15
MAX_RECORD_OVERHEAD = 0.30
# pipelined executor: overlap_gain = sync/pipelined wall time of a full
# recording sweep at >= 2 simulated devices. The acceptance target is
# >= 1.0 (pipelining overlaps shard/ckpt I/O with device compute); the
# hard floor sits below it because both sides run on the same noisy CI
# box — but a pipelined loop measurably SLOWER than synchronous means the
# double buffer broke, which no hardware difference explains.
MIN_OVERLAP_GAIN = 0.90
WARN_OVERLAP_GAIN = 1.0


def compare(base: dict, fresh: dict, tolerance: float, min_speedup: float):
    failures: list[str] = []
    warnings: list[str] = []
    rows: list[tuple[str, float, float, float]] = []

    base_res = base.get("results", {})
    fresh_res = fresh.get("results", {})
    ratios: dict[str, tuple[float, float, float]] = {}
    for scenario in sorted(base_res):
        b = base_res[scenario].get(IMPL, {}).get(METRIC)
        f = fresh_res.get(scenario, {}).get(IMPL, {}).get(METRIC)
        if b is None:
            continue
        if f is None:
            failures.append(f"{scenario}: missing from fresh results")
            continue
        ratios[scenario] = (b, f, f / b)

    # dividing by the median ratio cancels uniform hardware-speed skew;
    # what survives is a scenario regressing relative to its peers
    med = sorted(r for _, _, r in ratios.values())
    med = (med[len(med) // 2] if len(med) % 2
           else (med[len(med) // 2 - 1] + med[len(med) // 2]) / 2) if med else 1.0
    for scenario, (b, f, ratio) in ratios.items():
        norm = ratio / med if med > 0 else ratio
        rows.append((scenario, b, f, norm))
        if norm < 1.0 - tolerance:
            failures.append(
                f"{scenario}: {IMPL} {METRIC} regressed {1 - norm:.0%} "
                f"relative to the other scenarios "
                f"({b:.0f} -> {f:.0f}, median ratio {med:.2f}, "
                f"tolerance {tolerance:.0%})"
            )
        elif min(norm, ratio) < 1.0 - WARN_FRAC:
            warnings.append(
                f"{scenario}: {IMPL} {METRIC} down (raw {ratio:.2f}x, "
                f"normalized {norm:.2f}x; {b:.0f} -> {f:.0f}) — within "
                f"tolerance, watch it"
            )

    mixed = fresh.get("mixed", {})
    if mixed:
        largest = max(mixed, key=lambda k: mixed[k].get("n_scenarios", 0))
        speedup = mixed[largest].get("speedup_grouped_over_switch")
        if speedup is not None:
            rows.append((f"{largest} grouped/switch", min_speedup, speedup,
                         speedup / min_speedup))
            if speedup < min_speedup:
                failures.append(
                    f"{largest}: grouped dispatch speedup {speedup:.2f}x "
                    f"< required {min_speedup:.2f}x over switch"
                )
            elif speedup < WARN_SPEEDUP:
                warnings.append(
                    f"{largest}: grouped speedup {speedup:.2f}x is thin "
                    f"(full-scale baseline expects ~2.5x) — likely bench "
                    f"noise, worth a look if persistent"
                )
    else:
        warnings.append("fresh results carry no mixed suite — speedup unchecked")

    sharded = fresh.get("sharded", {})
    gain = sharded.get("overlap_gain")
    if gain is not None:
        rows.append(("pipelined/sync overlap", WARN_OVERLAP_GAIN, gain,
                     gain / WARN_OVERLAP_GAIN))
        if gain < MIN_OVERLAP_GAIN:
            failures.append(
                f"sharded: pipelined loop is {1/gain:.2f}x SLOWER than "
                f"synchronous (overlap_gain {gain:.2f} < floor "
                f"{MIN_OVERLAP_GAIN:.2f}) — the I/O double buffer is "
                f"costing throughput"
            )
        elif gain < WARN_OVERLAP_GAIN:
            warnings.append(
                f"sharded: overlap_gain {gain:.2f}x is below the >= 1.0 "
                f"target — pipelining shows no benefit on this run"
            )
    elif sharded.get("skipped"):
        warnings.append(
            f"sharded suite skipped ({sharded['skipped']}) — overlap "
            f"unchecked"
        )

    recording = fresh.get("recording", {})
    overhead = recording.get("overhead_frac")
    if overhead is not None:
        rows.append(("recording overhead", MAX_RECORD_OVERHEAD, overhead,
                     1.0 - overhead))
        if overhead > MAX_RECORD_OVERHEAD:
            failures.append(
                f"recording: {overhead:.0%} step-rate cost at "
                f"record_every=10 > hard limit {MAX_RECORD_OVERHEAD:.0%}"
            )
        elif overhead > WARN_RECORD_OVERHEAD:
            warnings.append(
                f"recording: {overhead:.0%} step-rate cost exceeds the "
                f"{WARN_RECORD_OVERHEAD:.0%} target — watch it"
            )

    return rows, warnings, failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_sweep.json")
    ap.add_argument("fresh", help="freshly measured JSON")
    ap.add_argument("--tolerance", type=float, default=0.40,
                    help="max allowed fractional regression (default 0.40)")
    ap.add_argument("--min-speedup", type=float, default=1.05,
                    help="required grouped-over-switch speedup on the "
                         "largest mix (default 1.05: grouped at or below "
                         "switch throughput = broken planner)")
    args = ap.parse_args()

    with open(args.baseline) as fh:
        base = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)

    rows, warnings, failures = compare(base, fresh, args.tolerance,
                                       args.min_speedup)

    lines = ["## sweep bench gate", "",
             f"baseline: `{base.get('platform', '?')}` "
             f"(quick={base.get('quick', False)}) vs fresh: "
             f"`{fresh.get('platform', '?')}` (quick={fresh.get('quick', False)})",
             "", "| check | baseline | fresh | normalized ratio |",
             "|---|---|---|---|"]
    for name, b, f, ratio in rows:
        fmt = ".2f" if max(abs(b), abs(f)) < 100 else ".0f"
        lines.append(f"| {name} | {b:{fmt}} | {f:{fmt}} | {ratio:.2f} |")
    for w in warnings:
        lines.append(f"- ⚠️ {w}")
    for f in failures:
        lines.append(f"- ❌ {f}")
    if not failures:
        lines.append("- ✅ no hard regressions")
    report = "\n".join(lines)
    print(report)

    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as fh:
            fh.write(report + "\n")

    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
