"""Docs can't rot: execute every fenced ``bash`` block, verify every link.

CI's ``docs`` job runs this over README.md and docs/ARCHITECTURE.md (see
.github/workflows/ci.yml) in a quick-mode environment (CPU backend, 4
forced host devices, ``PYTHONPATH=src``):

    python scripts/check_docs.py README.md docs/ARCHITECTURE.md

Rules:

- every fenced code block whose info string is exactly ``bash`` is run
  with ``bash -euo pipefail`` from the repo root; non-zero exit fails the
  check. Blocks are independent shells — the runner exports
  ``PYTHONPATH=src`` for all of them, so docs may omit the boilerplate.
- a block immediately preceded by an HTML comment containing
  ``check-docs: skip`` is listed but not executed (for commands whose
  cost is the point — the paper-scale sweep, the full benchmark run).
- every relative markdown link ``[text](path)`` must resolve to an
  existing file (anchors and absolute URLs are ignored) — broken
  intra-repo links fail the check.

Exit code: 1 if anything failed, 0 when the docs are green (a raw failure
count would wrap modulo 256 and could exit 0 on a badly broken tree).
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SKIP_MARK = "check-docs: skip"
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+?)(?:\s+\"[^\"]*\")?\)")
TIMEOUT = int(os.environ.get("CHECK_DOCS_TIMEOUT", "900"))


def extract_blocks(path: str) -> list[tuple[int, bool, str]]:
    """(start line, skipped?, script) for every ``bash`` fence in ``path``."""
    lines = open(path).readlines()
    blocks: list[tuple[int, bool, str]] = []
    i = 0
    while i < len(lines):
        stripped = lines[i].strip()
        if stripped.startswith("```"):
            info = stripped[3:].strip()
            fence_start = i
            body: list[str] = []
            i += 1
            while i < len(lines) and not lines[i].strip().startswith("```"):
                body.append(lines[i])
                i += 1
            if info == "bash":
                skipped = any(
                    SKIP_MARK in lines[j]
                    for j in range(max(0, fence_start - 2), fence_start)
                )
                blocks.append((fence_start + 1, skipped, "".join(body)))
        i += 1
    return blocks


def check_links(path: str) -> list[str]:
    errors = []
    text = open(path).read()
    # fenced code is not prose: links inside code blocks aren't links
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    base = os.path.dirname(os.path.abspath(path))
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (
            os.path.join(REPO_ROOT, rel.lstrip("/"))
            if rel.startswith("/")
            else os.path.join(base, rel)
        )
        if not os.path.exists(resolved):
            errors.append(f"{path}: broken link -> {target}")
    return errors


def run_block(path: str, line: int, script: str) -> bool:
    print(f"\n=== {path}:{line} ===\n{script.rstrip()}\n---")
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    try:
        proc = subprocess.run(
            ["bash", "-euo", "pipefail", "-c", script],
            cwd=REPO_ROOT, env=env, timeout=TIMEOUT,
        )
    except subprocess.TimeoutExpired:
        print(f"FAIL (timeout after {TIMEOUT}s)")
        return False
    if proc.returncode != 0:
        print(f"FAIL (exit {proc.returncode})")
        return False
    print("ok")
    return True


def main(argv: list[str]) -> int:
    files = argv or ["README.md", "docs/ARCHITECTURE.md"]
    failures = 0
    n_run = n_skip = 0
    for f in files:
        path = os.path.join(REPO_ROOT, f)
        if not os.path.exists(path):
            print(f"{f}: missing file")
            failures += 1
            continue
        for err in check_links(path):
            print(err)
            failures += 1
        for line, skipped, script in extract_blocks(path):
            if skipped:
                print(f"skip {f}:{line} (marked {SKIP_MARK!r})")
                n_skip += 1
                continue
            n_run += 1
            if not run_block(f, line, script):
                failures += 1
    print(f"\ncheck_docs: {n_run} blocks run, {n_skip} skipped, "
          f"{failures} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
