"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json artifacts.

Usage: PYTHONPATH=src python scripts/render_tables.py [mesh]
"""

from __future__ import annotations

import glob
import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}T"
    if b >= 1e9:
        return f"{b/1e9:.2f}G"
    if b >= 1e6:
        return f"{b/1e6:.1f}M"
    return f"{b:.0f}"


def main(mesh: str = "16x16") -> None:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            c = json.load(f)
        if c.get("mesh") != mesh:
            continue
        if "__" + mesh + ".json" not in os.path.basename(path):
            continue  # skip tagged (perf-iteration) artifacts
        rows.append(c)

    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda c: (c["arch"], order.get(c["shape"], 9)))

    print(f"### Roofline table — {mesh} mesh "
          f"(terms in seconds/step; v5e: 197 TF/s bf16, 819 GB/s HBM, "
          f"50 GB/s ICI)\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "useful-FLOPs | roofline-frac | bytes/dev | note |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for c in rows:
        if c["status"] == "skip":
            print(f"| {c['arch']} | {c['shape']} | — | — | — | — | — | — | "
                  f"— | SKIP: {c['reason'][:60]} |")
            continue
        r = c["roofline"]
        mem = c.get("memory", {})
        total_mem = sum(
            mem.get(k, 0)
            for k in ("argument_size_in_bytes", "temp_size_in_bytes",
                      "output_size_in_bytes")
        ) - mem.get("alias_size_in_bytes", 0)
        note = ""
        if c.get("train_overrides"):
            note = f"mb={c['train_overrides']['microbatches']}"
        print(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.3f} | "
            f"{r['roofline_fraction']:.4f} | {fmt_bytes(total_mem)} | "
            f"{note} |"
        )


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "16x16")
