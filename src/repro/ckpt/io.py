"""Pytree checkpoint I/O: numpy payloads + JSON manifest.

Checkpoints are stored logically (full arrays, flatten-order indexed), so a
restore can re-shard onto a *different* mesh than the one that saved — the
elastic-scaling requirement (DESIGN.md §7). Writes are atomic
(tmp-file + rename) so a failure mid-write never corrupts the latest
checkpoint — the property behind the paper's 100 % completion accounting.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np

from repro.utils.tree import tree_flatten_with_paths

MANIFEST = "manifest.json"
PAYLOAD = "arrays.npz"

# dtypes numpy's npz can't roundtrip natively → stored as raw same-width ints
_EXOTIC_AS_RAW = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _to_storable(arr: np.ndarray) -> np.ndarray:
    raw = _EXOTIC_AS_RAW.get(str(arr.dtype))
    return arr.view(raw) if raw is not None else arr


def _from_storable(arr: np.ndarray, logical_dtype: str) -> np.ndarray:
    if logical_dtype in _EXOTIC_AS_RAW:
        import ml_dtypes

        return arr.view(np.dtype(getattr(ml_dtypes, logical_dtype)))
    return arr


def _is_prng_key(x: Any) -> bool:
    try:
        return jax.dtypes.issubdtype(x.dtype, jax.dtypes.prng_key)
    except (AttributeError, TypeError):
        return False


def save_pytree(path: str, tree: Any, meta: dict | None = None) -> None:
    """Atomically save all array leaves of ``tree`` under directory ``path``."""
    os.makedirs(path, exist_ok=True)
    named = tree_flatten_with_paths(tree)
    arrays = {}
    index = []
    for i, (p, leaf) in enumerate(named):
        entry = {"path": p}
        if _is_prng_key(leaf):
            entry["prng_impl"] = str(jax.random.key_impl(leaf))
            leaf = jax.random.key_data(leaf)
        arr = np.asarray(jax.device_get(leaf))
        arrays[f"arr_{i}"] = _to_storable(arr)
        entry.update(shape=list(arr.shape), dtype=str(arr.dtype))
        index.append(entry)
    manifest = {"leaves": index, "meta": meta or {}}

    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp.npz")
    os.close(fd)
    np.savez(tmp, **arrays)
    os.replace(tmp, os.path.join(path, PAYLOAD))
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".json.tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, os.path.join(path, MANIFEST))


def load_pytree(path: str, like: Any, shardings: Any = None) -> Any:
    """Load a checkpoint into the structure of ``like``.

    ``like`` may hold concrete arrays or ShapeDtypeStructs; only its treedef
    and leaf dtypes are used. If ``shardings`` (a matching pytree of
    ``jax.sharding.Sharding`` or None leaves) is given, each leaf is placed
    with that sharding — this is where elastic re-meshing happens.
    """
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    payload = np.load(os.path.join(path, PAYLOAD))
    leaves_like, treedef = jax.tree.flatten(like)
    n = len(manifest["leaves"])
    if n != len(leaves_like):
        raise ValueError(
            f"checkpoint has {n} leaves but target structure has "
            f"{len(leaves_like)}"
        )
    out = []
    shard_leaves = (
        jax.tree.flatten(shardings)[0] if shardings is not None
        else [None] * n
    )
    for i, (ref, shard) in enumerate(zip(leaves_like, shard_leaves)):
        entry = manifest["leaves"][i]
        arr = _from_storable(payload[f"arr_{i}"], entry["dtype"])
        if "prng_impl" in entry:
            key = jax.random.wrap_key_data(
                jax.numpy.asarray(arr), impl=entry["prng_impl"]
            )
            out.append(key)
            continue
        want = np.dtype(getattr(ref, "dtype", arr.dtype))
        if arr.dtype != want:
            arr = arr.astype(want)
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out)


def load_meta(path: str) -> dict:
    with open(os.path.join(path, MANIFEST)) as f:
        return json.load(f)["meta"]


def latest_step(root: str) -> int | None:
    """Highest step among ``root/step_*`` checkpoint dirs, or None."""
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_"):
            if os.path.exists(os.path.join(root, name, MANIFEST)):
                try:
                    steps.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
    return max(steps) if steps else None
