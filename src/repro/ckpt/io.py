"""Pytree checkpoint I/O: numpy payloads + JSON manifest.

Checkpoints are stored logically (full arrays, flatten-order indexed), so a
restore can re-shard onto a *different* mesh than the one that saved — the
elastic-scaling requirement (DESIGN.md §7). Writes are crash-atomic
(tmp-file + fsync + rename, manifest committed last) and the manifest
carries a SHA-256 digest of the payload, so a kill mid-write or a torn /
bit-rotted payload is *detected* at restore time instead of silently
loaded — the durable-state half of the paper's 100 % completion
accounting (§5.2). :func:`verify_checkpoint` / :func:`valid_steps` are
the audit surface the unattended-run controller and the hardened
:class:`~repro.ckpt.manager.CheckpointManager` restore path key on.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Any

import jax
import numpy as np

from repro.utils.tree import tree_flatten_with_paths

MANIFEST = "manifest.json"
PAYLOAD = "arrays.npz"

# dtypes numpy's npz can't roundtrip natively → stored as raw same-width ints
_EXOTIC_AS_RAW = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _to_storable(arr: np.ndarray) -> np.ndarray:
    raw = _EXOTIC_AS_RAW.get(str(arr.dtype))
    return arr.view(raw) if raw is not None else arr


def _from_storable(arr: np.ndarray, logical_dtype: str) -> np.ndarray:
    if logical_dtype in _EXOTIC_AS_RAW:
        import ml_dtypes

        return arr.view(np.dtype(getattr(ml_dtypes, logical_dtype)))
    return arr


def _is_prng_key(x: Any) -> bool:
    try:
        return jax.dtypes.issubdtype(x.dtype, jax.dtypes.prng_key)
    except (AttributeError, TypeError):
        return False


def fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def fsync_dir(path: str) -> None:
    """Flush a directory entry (the rename itself) to disk; best-effort on
    filesystems that reject directory fsync."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def save_pytree(path: str, tree: Any, meta: dict | None = None) -> None:
    """Crash-atomically save all array leaves of ``tree`` under ``path``.

    Commit protocol: payload npz is written to a temp name, fsynced and
    renamed into place; the manifest (which embeds the payload's SHA-256)
    follows the same way. The manifest is therefore the commit point — a
    kill at any moment leaves either no manifest (checkpoint invisible)
    or a manifest whose digest vouches for a fully-written payload.
    """
    os.makedirs(path, exist_ok=True)
    named = tree_flatten_with_paths(tree)
    arrays = {}
    index = []
    for i, (p, leaf) in enumerate(named):
        entry = {"path": p}
        if _is_prng_key(leaf):
            entry["prng_impl"] = str(jax.random.key_impl(leaf))
            leaf = jax.random.key_data(leaf)
        arr = np.asarray(jax.device_get(leaf))
        arrays[f"arr_{i}"] = _to_storable(arr)
        entry.update(shape=list(arr.shape), dtype=str(arr.dtype))
        index.append(entry)

    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp.npz")
    os.close(fd)
    np.savez(tmp, **arrays)
    fsync_file(tmp)
    os.replace(tmp, os.path.join(path, PAYLOAD))

    manifest = {
        "leaves": index,
        "meta": meta or {},
        "payload_sha256": _sha256_file(os.path.join(path, PAYLOAD)),
        "n_leaves": len(index),
    }
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".json.tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(path, MANIFEST))
    fsync_dir(path)


def verify_checkpoint(path: str) -> bool:
    """True iff the checkpoint directory at ``path`` is complete and intact.

    Checks, cheapest first: manifest present and parseable, payload
    present, payload SHA-256 matches the manifest's recorded digest
    (legacy manifests without a digest skip this check), and the npz
    carries every indexed leaf. A kill mid-save, a truncated payload or a
    flipped bit all fail here instead of at (or worse, after) load time.
    """
    try:
        with open(os.path.join(path, MANIFEST)) as f:
            manifest = json.load(f)
        payload = os.path.join(path, PAYLOAD)
        if not os.path.exists(payload):
            return False
        digest = manifest.get("payload_sha256")
        if digest is not None and _sha256_file(payload) != digest:
            return False
        with np.load(payload) as z:
            names = set(z.files)
        return all(f"arr_{i}" in names
                   for i in range(len(manifest["leaves"])))
    except (OSError, ValueError, KeyError, json.JSONDecodeError):
        return False


def load_pytree(path: str, like: Any, shardings: Any = None) -> Any:
    """Load a checkpoint into the structure of ``like``.

    ``like`` may hold concrete arrays or ShapeDtypeStructs; only its treedef
    and leaf dtypes are used. If ``shardings`` (a matching pytree of
    ``jax.sharding.Sharding`` or None leaves) is given, each leaf is placed
    with that sharding — this is where elastic re-meshing happens.
    """
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    payload = np.load(os.path.join(path, PAYLOAD))
    leaves_like, treedef = jax.tree.flatten(like)
    n = len(manifest["leaves"])
    if n != len(leaves_like):
        raise ValueError(
            f"checkpoint has {n} leaves but target structure has "
            f"{len(leaves_like)}"
        )
    out = []
    shard_leaves = (
        jax.tree.flatten(shardings)[0] if shardings is not None
        else [None] * n
    )
    for i, (ref, shard) in enumerate(zip(leaves_like, shard_leaves)):
        entry = manifest["leaves"][i]
        arr = _from_storable(payload[f"arr_{i}"], entry["dtype"])
        if "prng_impl" in entry:
            key = jax.random.wrap_key_data(
                jax.numpy.asarray(arr), impl=entry["prng_impl"]
            )
            out.append(key)
            continue
        want = np.dtype(getattr(ref, "dtype", arr.dtype))
        if arr.dtype != want:
            arr = arr.astype(want)
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out)


def load_meta(path: str) -> dict:
    with open(os.path.join(path, MANIFEST)) as f:
        return json.load(f)["meta"]


def list_steps(root: str) -> list[int]:
    """All step indices with a committed manifest under ``root``, ascending
    (cheap scan — no payload verification; see :func:`valid_steps`)."""
    if not os.path.isdir(root):
        return []
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_"):
            if os.path.exists(os.path.join(root, name, MANIFEST)):
                try:
                    steps.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
    return sorted(steps)


def latest_step(root: str) -> int | None:
    """Highest step among ``root/step_*`` checkpoint dirs, or None."""
    steps = list_steps(root)
    return steps[-1] if steps else None


def valid_steps(root: str) -> list[int]:
    """Step indices whose checkpoint passes :func:`verify_checkpoint`,
    ascending — the restore-candidate list a kill mid-save can't poison."""
    return [
        s for s in list_steps(root)
        if verify_checkpoint(os.path.join(root, f"step_{s:09d}"))
    ]
