"""Checkpoint manager: step-indexed directories, retention, async writes.

Writes happen on a background thread (the paper's jobs checkpoint at slice
boundaries; training must not stall on I/O), with a barrier before the next
write or restore so at most one write is in flight.
"""

from __future__ import annotations

import os
import shutil
import threading
from typing import Any

import jax

from repro.ckpt.io import save_pytree, load_pytree, load_meta, latest_step


class CheckpointManager:
    def __init__(
        self,
        root: str,
        keep: int = 3,
        async_write: bool = True,
    ) -> None:
        self.root = root
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        os.makedirs(root, exist_ok=True)

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, meta: dict | None = None) -> None:
        self.wait()
        # materialize on host *before* handing to the writer thread so the
        # caller may donate/overwrite device buffers immediately
        host_tree = jax.tree.map(lambda x: jax.device_get(x), tree)
        meta = dict(meta or {}, step=step)

        def _write() -> None:
            save_pytree(self._dir(step), host_tree, meta)
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def restore(
        self, like: Any, step: int | None = None, shardings: Any = None
    ) -> tuple[Any, dict]:
        self.wait()
        if step is None:
            step = latest_step(self.root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        path = self._dir(step)
        return load_pytree(path, like, shardings), load_meta(path)

    def has_checkpoint(self) -> bool:
        self.wait()
        return latest_step(self.root) is not None

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_", 1)[1])
            for n in os.listdir(self.root)
            if n.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)
