"""Checkpoint manager: step-indexed directories, retention, async writes.

Writes happen on a background thread (the paper's jobs checkpoint at slice
boundaries; training must not stall on I/O), with a barrier before the next
write or restore so at most one write is in flight.

Crash safety (the unattended-run contract, paper §5.2): each save is
staged into a hidden ``.tmp-step_*`` directory, fsynced, and renamed into
place in one atomic directory move — a SIGKILL at any instant leaves
either the previous checkpoint set untouched or the new step fully
committed, never a half-written ``step_*`` dir. Restore only considers
checkpoints that pass :func:`repro.ckpt.io.verify_checkpoint` (manifest
present, payload SHA-256 matches) and automatically falls back past a
corrupt or torn newest checkpoint to the most recent valid one, recording
what it skipped in :attr:`last_skipped`.
"""

from __future__ import annotations

import os
import shutil
import threading
from typing import Any

import jax

from repro.ckpt.io import (
    save_pytree,
    load_pytree,
    load_meta,
    fsync_dir,
    list_steps,
    verify_checkpoint,
)

_TMP_PREFIX = ".tmp-step_"


class CheckpointManager:
    def __init__(
        self,
        root: str,
        keep: int = 3,
        async_write: bool = True,
    ) -> None:
        self.root = root
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        # steps the last restore() walk rejected (corrupt/torn), newest
        # first — the run journal surfaces these as ckpt_skipped events
        self.last_skipped: list[int] = []
        os.makedirs(root, exist_ok=True)

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, meta: dict | None = None) -> None:
        self.wait()
        # materialize on host *before* handing to the writer thread so the
        # caller may donate/overwrite device buffers immediately
        host_tree = jax.tree.map(lambda x: jax.device_get(x), tree)
        meta = dict(meta or {}, step=step)

        def _write() -> None:
            # stage → fsync → rename: the step dir appears atomically, so
            # a kill mid-save can never produce a half-written step_* dir
            final = self._dir(step)
            tmp = os.path.join(
                self.root, f"{_TMP_PREFIX}{step:09d}-{os.getpid()}"
            )
            shutil.rmtree(tmp, ignore_errors=True)
            save_pytree(tmp, host_tree, meta)
            if os.path.isdir(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            fsync_dir(self.root)
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def restore(
        self, like: Any, step: int | None = None, shardings: Any = None
    ) -> tuple[Any, dict]:
        """Load the newest *valid* checkpoint (or ``step`` exactly).

        With ``step=None`` the manager walks committed steps newest-first,
        skipping any directory that fails integrity verification or whose
        payload errors at load time — a kill mid-save or a corrupted write
        costs at most one step of progress, never the run. Skipped steps
        land in :attr:`last_skipped`. An explicit ``step`` is strict: a
        corrupt target raises instead of silently loading garbage.
        """
        self.wait()
        self.last_skipped = []
        if step is not None:
            path = self._dir(step)
            if not verify_checkpoint(path):
                raise FileNotFoundError(
                    f"checkpoint step {step} at {path} is missing or fails "
                    "integrity verification"
                )
            return load_pytree(path, like, shardings), load_meta(path)
        for s in sorted(list_steps(self.root), reverse=True):
            path = self._dir(s)
            if not verify_checkpoint(path):
                self.last_skipped.append(s)
                continue
            try:
                return load_pytree(path, like, shardings), load_meta(path)
            except Exception:
                # digest said intact but the load still failed (e.g. leaf
                # structure drift) — fall back to the next-oldest step
                self.last_skipped.append(s)
        raise FileNotFoundError(
            f"no valid checkpoints under {self.root}"
            + (f" (skipped corrupt steps {self.last_skipped})"
               if self.last_skipped else "")
        )

    def has_checkpoint(self) -> bool:
        """True iff at least one checkpoint passes integrity verification
        — an incomplete or corrupted save never counts as resumable."""
        self.wait()
        return any(
            verify_checkpoint(self._dir(s)) for s in list_steps(self.root)
        )

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_", 1)[1])
            for n in os.listdir(self.root)
            if n.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)
        # stale staging dirs from a killed writer are dead weight: only
        # this process's in-flight tmp (none, _gc runs post-rename) is live
        for n in os.listdir(self.root):
            if n.startswith(_TMP_PREFIX):
                shutil.rmtree(os.path.join(self.root, n),
                              ignore_errors=True)
