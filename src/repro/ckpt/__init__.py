from repro.ckpt.io import (
    save_pytree,
    load_pytree,
    latest_step,
    valid_steps,
    verify_checkpoint,
)
from repro.ckpt.manager import CheckpointManager

__all__ = [
    "save_pytree",
    "load_pytree",
    "latest_step",
    "valid_steps",
    "verify_checkpoint",
    "CheckpointManager",
]
