"""Small pytree utilities used across the framework."""

from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np


def _leaf_bytes(x: Any) -> int:
    shape = getattr(x, "shape", None)
    dtype = getattr(x, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize


def tree_bytes(tree: Any) -> int:
    """Total bytes of all array leaves (works on ShapeDtypeStruct too)."""
    return sum(_leaf_bytes(x) for x in jax.tree.leaves(tree))


def tree_param_count(tree: Any) -> int:
    """Total element count of all array leaves."""
    return sum(
        int(np.prod(getattr(x, "shape", ()), dtype=np.int64))
        for x in jax.tree.leaves(tree)
    )


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    """Flatten a pytree to (path-string, leaf) pairs."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_path_str(path), leaf) for path, leaf in leaves]


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """Map ``fn(path_string, leaf)`` over a pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(_path_str(path), leaf), tree
    )
