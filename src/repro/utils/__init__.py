from repro.utils.tree import (
    tree_bytes,
    tree_param_count,
    tree_flatten_with_paths,
    tree_map_with_path,
)
from repro.utils.hlo import collective_bytes, CollectiveStats
from repro.utils.timing import Timer

__all__ = [
    "tree_bytes",
    "tree_param_count",
    "tree_flatten_with_paths",
    "tree_map_with_path",
    "collective_bytes",
    "CollectiveStats",
    "Timer",
]
