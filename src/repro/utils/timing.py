"""Wall-clock timing helper for benchmarks (CPU-host measurements only)."""

from __future__ import annotations

import time
from contextlib import contextmanager


class Timer:
    """Accumulating timer; ``with timer.measure(): ...`` adds one sample."""

    def __init__(self) -> None:
        self.samples: list[float] = []

    @contextmanager
    def measure(self):
        t0 = time.perf_counter()
        yield
        self.samples.append(time.perf_counter() - t0)

    @property
    def total(self) -> float:
        return sum(self.samples)

    @property
    def mean(self) -> float:
        return self.total / len(self.samples) if self.samples else 0.0

    @property
    def best(self) -> float:
        return min(self.samples) if self.samples else 0.0

    def us_per_call(self) -> float:
        return self.best * 1e6


def bench(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Best-of-``iters`` seconds for ``fn(*args)``, blocking on the result."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t = Timer()
    for _ in range(iters):
        with t.measure():
            jax.block_until_ready(fn(*args))
    return t.best
