"""Parse compiled HLO text for collective traffic — the §Roofline collective term.

``compiled.cost_analysis()`` reports FLOPs and bytes-accessed but not
collective traffic, so we parse the post-SPMD-partitioning HLO dump and sum
the bytes moved per device for every collective op, using standard
ring-algorithm wire formulas:

    all-gather          : out_bytes      * (n-1)/n
    reduce-scatter      : in_bytes       * (n-1)/n
    all-reduce          : 2 * out_bytes  * (n-1)/n      (RS + AG)
    all-to-all          : out_bytes      * (n-1)/n
    collective-permute  : out_bytes                      (full buffer hop)

Shapes in partitioned HLO are already per-device, so the formulas give wire
bytes per device directly.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

import numpy as np

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1,
    "s16": 2, "u16": 2,
    "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# one shape like  bf16[16,4096,3584]{2,1,0}  or  f32[] or pred[4]
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")

# op line:  %name = <shape-or-tuple> <op>(...operands...), ... replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?P<out>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<variant>-start)?\("
    r"(?P<operands>[^)]*)\)"
)

# explicit groups: replica_groups={{0,1,2},{3,4,5}}
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
# iota v2 form: replica_groups=[32,16]<=[512]  → group size is 2nd entry
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(text: str) -> int:
    """Sum bytes over every shape literal appearing in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        if dims:
            n = int(np.prod([int(d) for d in dims.split(",")], dtype=np.int64))
        else:
            n = 1
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        entries = [e for e in m.group(1).split(",") if e.strip() != ""]
        return max(len(entries), 1)
    return 1


@dataclasses.dataclass
class CollectiveStats:
    """Collective traffic summary for one compiled executable."""

    counts: dict[str, int]
    payload_bytes: dict[str, int]  # raw buffer bytes per op kind
    wire_bytes: dict[str, float]   # ring-model wire bytes per device
    total_wire_bytes: float

    def summary(self) -> str:
        lines = [f"total wire bytes/device: {self.total_wire_bytes:,.0f}"]
        for op in sorted(self.counts):
            lines.append(
                f"  {op:<20s} n={self.counts[op]:<4d} "
                f"payload={self.payload_bytes[op]:,} wire={self.wire_bytes[op]:,.0f}"
            )
        return "\n".join(lines)


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Parse HLO text and compute per-device collective wire traffic."""
    counts: dict[str, int] = defaultdict(int)
    payload: dict[str, int] = defaultdict(int)
    wire: dict[str, float] = defaultdict(float)

    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        m = _OP_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        # skip the -done halves of async pairs; -start carries the shapes
        if f"{op}-done" in line:
            continue
        out_bytes = _shape_bytes(m.group("out"))
        in_bytes = _shape_bytes(m.group("operands"))
        n = _group_size(line)
        if op == "all-gather":
            pay = out_bytes
            w = out_bytes * (n - 1) / max(n, 1)
        elif op == "reduce-scatter":
            pay = in_bytes
            w = in_bytes * (n - 1) / max(n, 1)
        elif op == "all-reduce":
            # async -start output can be a (in, out) tuple; use operand bytes
            pay = in_bytes if m.group("variant") else out_bytes
            w = 2.0 * pay * (n - 1) / max(n, 1)
        elif op == "all-to-all":
            pay = out_bytes
            w = out_bytes * (n - 1) / max(n, 1)
        else:  # collective-permute
            pay = out_bytes
            w = float(out_bytes)
        counts[op] += 1
        payload[op] += pay
        wire[op] += w

    return CollectiveStats(
        counts=dict(counts),
        payload_bytes=dict(payload),
        wire_bytes=dict(wire),
        total_wire_bytes=float(sum(wire.values())),
    )
