"""Sharding rules: param/batch/cache PartitionSpecs with divisibility fallback.

Strategy (MaxText-style, DESIGN.md §4):
- mesh axes: ``("data", "model")`` single pod, ``("pod", "data", "model")``
  multi-pod. ``pod``+``data`` together form the DP/FSDP axis group.
- params: FSDP-shard the d_model ("embed") dim over ``data``; tensor-parallel
  shard heads / d_ff / experts / vocab over ``model``.
- activations/batch: batch over ``("pod","data")``.
- decode KV caches: batch over ``data``, cache sequence over ``model``
  (context-parallel decode).

Every rule is divisibility-checked against the actual dim size and falls back
to replication — one rule set stays valid across all 10 architectures (e.g.
recurrentgemma's 10 heads or MQA kv=1 simply replicate over ``model``).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import ModelConfig
from repro.utils.tree import tree_map_with_path


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tp_axis(mesh: Mesh) -> str | None:
    return "model" if "model" in mesh.axis_names else None


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fit(mesh: Mesh, size: int, *candidates):
    """First candidate axis (or axis tuple) that evenly divides ``size``."""
    for cand in candidates:
        if cand is None:
            continue
        if size % _axis_size(mesh, cand) == 0:
            return cand
    return None


# --------------------------------------------------------------------------
# parameter specs
# --------------------------------------------------------------------------

def _param_leaf_spec(
    cfg: ModelConfig, mesh: Mesh, path: str, shape: tuple[int, ...]
) -> P:
    """PartitionSpec for one param leaf, by path suffix + shape."""
    parts = path.split("/")
    name = parts[-1]
    parent = parts[-2] if len(parts) > 1 else ""
    dp = dp_axes(mesh)
    tp = tp_axis(mesh)
    nd = len(shape)

    # leading stacked-layer (scan) axis stays unsharded
    lead: tuple = ()
    if parts and parts[0] in ("blocks", "enc_blocks", "dec_blocks"):
        lead = (None,)
        shape = shape[1:]
        nd -= 1

    def spec(*axes):
        resolved = []
        used: set = set()
        for ax, dim in zip(axes, shape):
            cand = ax
            if cand is not None:
                names = set(cand) if isinstance(cand, tuple) else {cand}
                if (used & names) or dim % _axis_size(mesh, cand) != 0:
                    cand = None
                else:
                    used |= names
            resolved.append(cand)
        return P(*lead, *resolved)

    # ---- embeddings / heads --------------------------------------------
    if name == "table":                       # [V, d]
        return spec(tp, dp)
    if parent == "lm_head" and name == "w":   # [d, V]
        return spec(dp, tp)

    # ---- MoE -------------------------------------------------------------
    if name == "router":                      # [d, E]
        return spec(dp, tp)
    if nd == 3 and shape[0] == cfg.n_experts and name in (
        "wi_gate", "wi_up", "wo"
    ):
        if name == "wo":                      # [E, m, d]
            return spec(tp, None, dp)
        return spec(tp, dp, None)             # [E, d, m]

    # ---- attention --------------------------------------------------------
    if name in ("wq", "q_proj") and nd == 3:  # [d, H, hd]
        return spec(dp, tp, None)
    if name in ("wk", "wv") and nd == 3:      # [d, K, hd]
        return spec(dp, tp, None)
    if name == "wo" and nd == 3:              # [H, hd, d]
        return spec(tp, None, dp)
    if name in ("bq", "bk", "bv"):            # [H, hd]
        return spec(tp, None)

    # ---- MLA ----------------------------------------------------------------
    if name == "q_a":                         # [d, q_lora]
        return spec(dp, None)
    if name in ("q_b", "kv_b"):               # [q_lora|L, H, dn+dr|dn+dv]
        # TP on heads when divisible; else REPLICATE — these are small
        # (low-rank) and dp-sharding them turns every layer's expansion into
        # a collective-permute storm (observed on minicpm3 prefill_32k)
        return spec(None, tp, None)
    if name == "kv_a":                        # [d, L+dr]
        return spec(dp, None)

    # ---- dense FFN -----------------------------------------------------------
    if name in ("wi_gate", "wi_up") and nd == 2:
        # recurrent block in-projs [d, W] and ffn [d, m]: TP the wide dim
        return spec(dp, tp)
    if name == "wo" and nd == 2:              # [m|W, d] or rwkv [d, d]
        return spec(tp, dp)

    # ---- recurrent (RG-LRU) -----------------------------------------------
    if name == "wi_x":                        # [d, W]
        return spec(dp, tp)
    if name == "conv_w":                      # [cw, W]
        return spec(None, tp)
    if name in ("conv_b", "a_param", "ba", "bx"):
        return spec(tp)
    if name in ("wa", "wx") and nd == 3:      # [h, hd, hd] block-diag gates
        return spec(tp, None, None)

    # ---- RWKV ------------------------------------------------------------------
    if parent == "time_mix" and name in ("wr", "wk", "wv", "wg") and nd == 2:
        return spec(dp, tp)                   # [d, d]
    if parent == "channel_mix" and name in ("wk",) and nd == 2:
        return spec(dp, tp)                   # [d, m]
    if parent == "channel_mix" and name in ("wv",) and nd == 2:
        return spec(tp, dp)                   # [m, d]
    if parent == "channel_mix" and name in ("wr",) and nd == 2:
        return spec(dp, tp)
    if name == "ts_w1":                       # [d, 5*lora]
        return spec(dp, None)
    if name == "ts_w2":                       # [5, lora, d]
        return spec(None, None, dp)
    if name in ("w_lora1",):
        return spec(dp, None)
    if name in ("w_lora2",):
        return spec(None, dp)
    if name == "u":                           # [H, hd]
        return spec(tp, None)
    if name == "frame_proj":
        return spec(dp, tp)

    # ---- everything else (norm scales, mus, biases): replicate -------------
    return P(*lead, *([None] * nd))


def param_specs(cfg: ModelConfig, abstract_params: Any, mesh: Mesh) -> Any:
    return tree_map_with_path(
        lambda path, leaf: _param_leaf_spec(cfg, mesh, path, leaf.shape),
        abstract_params,
    )


def param_shardings(cfg: ModelConfig, abstract_params: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_specs(cfg, abstract_params, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


def opt_state_shardings(
    cfg: ModelConfig, abstract_opt_state: Any, abstract_params: Any, mesh: Mesh
) -> Any:
    """Adam m/v mirror the param sharding; scalar step is replicated."""
    pspecs = param_specs(cfg, abstract_params, mesh)

    def one(leaf_spec):
        return NamedSharding(mesh, leaf_spec)

    mirrored = jax.tree.map(one, pspecs, is_leaf=lambda x: isinstance(x, P))
    step_shard = NamedSharding(mesh, P())
    return type(abstract_opt_state)(
        step=step_shard, m=mirrored, v=mirrored
    )


# --------------------------------------------------------------------------
# batch / cache specs
# --------------------------------------------------------------------------

def batch_specs(mesh: Mesh, abstract_batch: Any) -> Any:
    """Batch dim → DP axes (divisibility-checked: a global batch of 1, as in
    long_500k, replicates); M-RoPE position arrays are [3, B, S]."""
    dp = dp_axes(mesh)

    def fit(dim):
        return dp if (dp and dim % _axis_size(mesh, dp) == 0) else None

    def one(path, leaf):
        if path.endswith("mrope_pos"):
            return P(None, fit(leaf.shape[1]),
                     *([None] * (len(leaf.shape) - 2)))
        return P(fit(leaf.shape[0]), *([None] * (len(leaf.shape) - 1)))

    return tree_map_with_path(one, abstract_batch)


def batch_shardings(mesh: Mesh, abstract_batch: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        batch_specs(mesh, abstract_batch),
        is_leaf=lambda x: isinstance(x, P),
    )


def _cache_leaf_spec(
    mesh: Mesh, batch: int, path: str, shape: tuple[int, ...]
) -> P:
    """Decode caches: batch→data, long sequence dim→model (context parallel).

    Handles: kv caches {k,v:[B,S,K,hd], pos:[B,S]}, MLA {c:[B,S,L],
    k_rope:[B,S,dr]}, recurrent {h:[B,W], conv:[B,cw,W]},
    rwkv {S:[B,H,dk,dv], x_prev_*:[B,d]}, whisper cross {k,v:[L,B,T,H,hd]}.
    A leading stacked-layer axis (size != batch) is skipped.
    """
    dp = dp_axes(mesh)
    tp = tp_axis(mesh)
    parts = path.split("/")
    name = parts[-1]

    lead: tuple = ()
    # stacked-layer leading axis: caches under the scanned segments
    if parts[0] in ("blocks", "self", "cross"):
        lead = (None,)
        shape = shape[1:]

    def fit(axis, dim):
        return axis if (axis and dim % _axis_size(mesh, axis) == 0) else None

    bspec = fit(dp, shape[0])
    rest = [None] * (len(shape) - 1)
    if name in ("k", "v", "pos", "c", "k_rope") and len(shape) >= 2:
        rest[0] = fit(tp, shape[1])               # cache sequence dim
    elif name == "S" and len(shape) == 4:          # rwkv state [B,H,dk,dv]
        rest[0] = fit(tp, shape[1])
        if rest[0] is None:
            rest[2] = fit(tp, shape[3])
    elif name in ("h",) and len(shape) == 2:       # rglru state [B,W]
        rest[0] = fit(tp, shape[1])
    elif name == "conv" and len(shape) == 3:       # conv history [B,cw,W]
        rest[1] = fit(tp, shape[2])
    return P(*lead, bspec, *rest)


def cache_specs(mesh: Mesh, abstract_cache: Any, batch: int) -> Any:
    return tree_map_with_path(
        lambda path, leaf: _cache_leaf_spec(mesh, batch, path, leaf.shape),
        abstract_cache,
    )


def cache_shardings(mesh: Mesh, abstract_cache: Any, batch: int) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        cache_specs(mesh, abstract_cache, batch),
        is_leaf=lambda x: isinstance(x, P),
    )
