"""Activation-sharding context: models call ``constrain(x, ...)`` with
logical axis tags; a launcher that activates a mesh turns those into
``with_sharding_constraint`` hints. Without an active mesh (smoke tests,
single-device examples) constraints are no-ops.

Tags: "dp" (batch → pod+data axes), "tp" (→ model axis), None (replicate).
Divisibility is checked per-dim, falling back to None — same policy as the
parameter rules.
"""

from __future__ import annotations

import contextlib
from contextvars import ContextVar

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.axes import dp_axes, tp_axis, _axis_size

_ACTIVE: ContextVar[Mesh | None] = ContextVar("activation_mesh", default=None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh | None):
    token = _ACTIVE.set(mesh)
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def tp_size() -> int:
    """Size of the active mesh's 'model' axis (1 when no mesh active)."""
    mesh = _ACTIVE.get()
    if mesh is None:
        return 1
    ax = tp_axis(mesh)
    return int(mesh.shape[ax]) if ax else 1


def constrain(x: jax.Array, *tags: str | None) -> jax.Array:
    """Apply a sharding hint; no-op without an active mesh."""
    mesh = _ACTIVE.get()
    if mesh is None or len(tags) != x.ndim:
        return x
    dp = dp_axes(mesh)
    tp = tp_axis(mesh)
    entries = []
    used: set = set()
    for tag, dim in zip(tags, x.shape):
        axis = dp if tag == "dp" else tp if tag == "tp" else None
        if axis is not None:
            names = set(axis) if isinstance(axis, tuple) else {axis}
            if (used & names) or dim % _axis_size(mesh, axis) != 0:
                axis = None
            else:
                used |= names
        entries.append(axis)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries))
    )
