from repro.sharding.axes import (
    dp_axes,
    tp_axis,
    param_specs,
    param_shardings,
    batch_specs,
    batch_shardings,
    cache_specs,
    cache_shardings,
    opt_state_shardings,
)

__all__ = [
    "dp_axes",
    "tp_axis",
    "param_specs",
    "param_shardings",
    "batch_specs",
    "batch_shardings",
    "cache_specs",
    "cache_shardings",
    "opt_state_shardings",
]
