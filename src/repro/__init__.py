"""repro — Webots.HPC reproduced as a JAX multi-pod simulation + training framework.

The paper's contribution (a parallel, fault-tolerant simulation sweep pipeline
feeding an ML phase) lives in :mod:`repro.core`. The ML-phase substrate (model
zoo, distributed train/serve) lives in the sibling subpackages.
"""

__version__ = "0.1.0"
