"""Transformer blocks: one (init, apply, prefill, decode) family per layer kind.

Layer kinds (``ModelConfig.layer_pattern`` entries):
- "global"    — full causal attention + FFN/MoE
- "local"     — sliding-window attention + FFN/MoE
- "recurrent" — Griffin RG-LRU block + FFN
- "rwkv"      — RWKV6 time mix + channel mix

All blocks are pre-norm residual; gemma2 additionally applies post-norms
(``cfg.post_norms``). MoE-ness is decided at stack level (scanned segments
are homogeneous), so a block is constructed as either dense or MoE.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.attention import (
    attn_apply,
    attn_decode,
    attn_init,
    attn_prefill,
    kv_cache_init,
    mla_apply,
    mla_cache_init,
    mla_decode,
    mla_init,
    mla_prefill,
)
from repro.models.common import Params, norm, norm_init
from repro.models.ffn import ffn_apply, ffn_init, moe_apply, moe_init
from repro.models.recurrent import (
    recurrent_block_apply,
    recurrent_block_init,
    recurrent_block_step,
    recurrent_cache_init,
    rwkv_cache_init,
    rwkv_channel_mix_apply,
    rwkv_channel_mix_init,
    rwkv_channel_mix_step,
    rwkv_time_mix_apply,
    rwkv_time_mix_init,
    rwkv_time_mix_step,
)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def block_init(
    key, cfg: ModelConfig, kind: str, moe: bool, d_ff: int | None = None
) -> Params:
    """One block's params. ``moe`` selects MoE vs dense FFN (attn kinds)."""
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: Params = {"norm1": norm_init(cfg, d), "norm2": norm_init(cfg, d)}
    if kind == "rwkv":
        p["time_mix"] = rwkv_time_mix_init(ks[0], cfg)
        p["channel_mix"] = rwkv_channel_mix_init(ks[1], cfg)
        return p
    if kind == "recurrent":
        p["mixer"] = recurrent_block_init(ks[0], cfg)
    elif cfg.use_mla:
        p["mixer"] = mla_init(ks[0], cfg)
    else:
        p["mixer"] = attn_init(ks[0], cfg)
    if moe:
        p["ffn"] = moe_init(ks[1], cfg)
    else:
        p["ffn"] = ffn_init(ks[1], cfg, d_ff=d_ff)
    if cfg.post_norms:
        p["post_norm1"] = norm_init(cfg, d)
        p["post_norm2"] = norm_init(cfg, d)
    return p


# --------------------------------------------------------------------------
# full-sequence apply (train / prefill compute)
# --------------------------------------------------------------------------

def _residual(cfg: ModelConfig, p: Params, x, sub, post_key: str):
    if cfg.post_norms:
        sub = norm(cfg, p[post_key], sub)
    return x + sub


def block_apply(
    cfg: ModelConfig,
    p: Params,
    kind: str,
    moe: bool,
    x: jax.Array,
    positions: jax.Array,
    mrope_pos: jax.Array | None = None,
    causal: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns (x, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "rwkv":
        h = norm(cfg, p["norm1"], x)
        x = x + rwkv_time_mix_apply(cfg, p["time_mix"], h)
        h = norm(cfg, p["norm2"], x)
        x = x + rwkv_channel_mix_apply(cfg, p["channel_mix"], h)
        return x, aux

    h = norm(cfg, p["norm1"], x)
    if kind == "recurrent":
        sub = recurrent_block_apply(cfg, p["mixer"], h)
    elif cfg.use_mla:
        sub = mla_apply(cfg, p["mixer"], h, positions)
    else:
        sub = attn_apply(cfg, p["mixer"], h, positions, kind,
                         mrope_pos=mrope_pos, causal=causal)
    x = _residual(cfg, p, x, sub, "post_norm1")

    h = norm(cfg, p["norm2"], x)
    if moe:
        sub, aux = moe_apply(cfg, p["ffn"], h)
    else:
        sub = ffn_apply(cfg, p["ffn"], h)
    x = _residual(cfg, p, x, sub, "post_norm2")
    return x, aux


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------

def block_cache_init(
    cfg: ModelConfig, kind: str, batch: int, max_seq: int
) -> dict:
    if kind == "rwkv":
        return rwkv_cache_init(cfg, batch)
    if kind == "recurrent":
        return recurrent_cache_init(cfg, batch)
    if cfg.use_mla:
        return mla_cache_init(cfg, batch, max_seq)
    return kv_cache_init(cfg, batch, max_seq, kind)


def block_prefill(
    cfg: ModelConfig,
    p: Params,
    kind: str,
    moe: bool,
    x: jax.Array,
    positions: jax.Array,
    cache: dict,
    mrope_pos: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    """Full-seq compute + cache fill. Recurrent kinds advance their state by
    scanning the sequence (their 'cache' is the final state)."""
    if kind == "rwkv":
        h = norm(cfg, p["norm1"], x)
        # run full sequence, then recompute final state via one batched pass
        y = rwkv_time_mix_apply(cfg, p["time_mix"], h)
        x = x + y
        h2 = norm(cfg, p["norm2"], x)
        x = x + rwkv_channel_mix_apply(cfg, p["channel_mix"], h2)
        cache = _rwkv_state_from_prefill(cfg, p, h, h2, cache)
        return x, cache
    if kind == "recurrent":
        h = norm(cfg, p["norm1"], x)
        sub, cache = _recurrent_prefill(cfg, p["mixer"], h, cache)
        x = _residual(cfg, p, x, sub, "post_norm1")
        h = norm(cfg, p["norm2"], x)
        x = _residual(cfg, p, x, ffn_apply(cfg, p["ffn"], h), "post_norm2")
        return x, cache

    h = norm(cfg, p["norm1"], x)
    if cfg.use_mla:
        sub, cache = mla_prefill(cfg, p["mixer"], h, positions, cache)
    else:
        sub, cache = attn_prefill(
            cfg, p["mixer"], h, positions, cache, kind, mrope_pos
        )
    x = _residual(cfg, p, x, sub, "post_norm1")
    h = norm(cfg, p["norm2"], x)
    if moe:
        sub, _ = moe_apply(cfg, p["ffn"], h)
    else:
        sub = ffn_apply(cfg, p["ffn"], h)
    x = _residual(cfg, p, x, sub, "post_norm2")
    return x, cache


def block_decode(
    cfg: ModelConfig,
    p: Params,
    kind: str,
    moe: bool,
    x: jax.Array,          # [B, 1, D]
    pos: jax.Array,        # [B]
    cache: dict,
    mrope_pos: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    if kind == "rwkv":
        h = norm(cfg, p["norm1"], x)
        y, cache = rwkv_time_mix_step(cfg, p["time_mix"], h, cache)
        x = x + y
        h = norm(cfg, p["norm2"], x)
        y, cache = rwkv_channel_mix_step(cfg, p["channel_mix"], h, cache)
        x = x + y
        return x, cache

    h = norm(cfg, p["norm1"], x)
    if kind == "recurrent":
        sub, cache = recurrent_block_step(cfg, p["mixer"], h, cache)
    elif cfg.use_mla:
        sub, cache = mla_decode(cfg, p["mixer"], h, pos, cache)
    else:
        sub, cache = attn_decode(
            cfg, p["mixer"], h, pos, cache, kind, mrope_pos
        )
    x = _residual(cfg, p, x, sub, "post_norm1")
    h = norm(cfg, p["norm2"], x)
    if moe:
        sub, _ = moe_apply(cfg, p["ffn"], h)
    else:
        sub = ffn_apply(cfg, p["ffn"], h)
    x = _residual(cfg, p, x, sub, "post_norm2")
    return x, cache


# ---- prefill state helpers for recurrent kinds -----------------------------

def _recurrent_prefill(cfg: ModelConfig, p: Params, h, cache):
    """Griffin block full-seq + final (h, conv) state extraction."""
    from repro.models.recurrent import _causal_conv, rglru_scan, _rglru_gates

    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, p["wi_gate"]),
                       approximate=True)
    u = jnp.einsum("bsd,dw->bsw", h, p["wi_x"])
    conv = _causal_conv(p, u, cfg.conv_width)
    y = rglru_scan(p["lru"], conv, cfg.n_heads)
    out = jnp.einsum("bsw,wd->bsd", gate * y, p["wo"])
    cw = cfg.conv_width
    new_cache = {
        "h": y[:, -1].astype(jnp.float32),
        "conv": u[:, -(cw - 1):],
    }
    return out, new_cache


def _rwkv_state_from_prefill(cfg, p, h_tm, h_cm, cache):
    """Recompute the RWKV recurrent state after a full-seq pass."""
    from repro.models.recurrent import _wkv_inputs

    b, s, d = h_tm.shape
    hd = cfg.rwkv_head_dim
    nh = d // hd
    x_prev = jnp.pad(h_tm, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, w, _ = _wkv_inputs(cfg, p["time_mix"], h_tm, x_prev)

    def step(S, inp):
        k_t, v_t, w_t = inp
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        return w_t[..., None] * S + kv, None

    S0 = cache["S"]
    seq = (
        k.transpose(1, 0, 2, 3).astype(jnp.float32),
        v.transpose(1, 0, 2, 3).astype(jnp.float32),
        w.transpose(1, 0, 2, 3),
    )
    S, _ = jax.lax.scan(step, S0, seq)
    return {"S": S, "x_prev_tm": h_tm[:, -1], "x_prev_cm": h_cm[:, -1]}
