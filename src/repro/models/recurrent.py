"""Linear-recurrence layers: RG-LRU (Griffin/recurrentgemma) and RWKV-6 time mix.

Both have O(1) decode state — the property that makes their ``long_500k``
cells viable. Training/prefill uses an associative scan (RG-LRU) or a
sequential ``lax.scan`` (RWKV6 reference); the chunked Pallas kernels in
``repro.kernels`` are the TPU execution path, validated against these.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.common import Params, cdtype, dense_init

SQRT_8 = 8.0  # RG-LRU 'c' constant


# --------------------------------------------------------------------------
# RG-LRU recurrence core
# --------------------------------------------------------------------------

def rglru_core_init(key, cfg: ModelConfig) -> Params:
    dt = cdtype(cfg)
    w = cfg.lru_width
    h = cfg.n_heads
    hd = w // h
    k1, k2, k3 = jax.random.split(key, 3)
    # Λ init so that a = exp(-8·softplus(Λ)·σ(...)) starts near 0.9..0.999
    lam = jax.random.uniform(k1, (w,), jnp.float32, 0.9, 0.999)
    a_param = jnp.log(jnp.exp(-jnp.log(lam) / SQRT_8) - 1.0)  # softplus^-1
    return {
        "a_param": a_param.astype(jnp.float32),
        "wa": dense_init(k2, (h, hd, hd), hd, dt),  # block-diagonal gates
        "wx": dense_init(k3, (h, hd, hd), hd, dt),
        "ba": jnp.zeros((w,), dt),
        "bx": jnp.zeros((w,), dt),
    }


def _rglru_gates(p: Params, x: jax.Array, h: int):
    """x: [B, S, W] → (log_a, gated_x): the per-step decay and gated input."""
    b, s, w = x.shape
    hd = w // h
    xh = x.reshape(b, s, h, hd)
    ra = jax.nn.sigmoid(
        jnp.einsum("bshd,hde->bshe", xh, p["wa"]).reshape(b, s, w)
        + p["ba"]
    ).astype(jnp.float32)
    rx = jax.nn.sigmoid(
        jnp.einsum("bshd,hde->bshe", xh, p["wx"]).reshape(b, s, w)
        + p["bx"]
    ).astype(jnp.float32)
    log_a = -SQRT_8 * jax.nn.softplus(p["a_param"]) * ra   # [B,S,W] f32
    a2 = jnp.exp(2.0 * log_a)
    gated_x = x.astype(jnp.float32) * rx * jnp.sqrt(
        jnp.maximum(1.0 - a2, 1e-6)
    )
    return log_a, gated_x


def rglru_scan(p: Params, x: jax.Array, h: int) -> jax.Array:
    """Full-sequence RG-LRU via associative scan. x: [B,S,W] → [B,S,W]."""
    log_a, gx = _rglru_gates(p, x, h)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 + a2, b1 * jnp.exp(a2) + b2

    la, y = jax.lax.associative_scan(combine, (log_a, gx), axis=1)
    return y.astype(x.dtype)


def rglru_step(
    p: Params, x: jax.Array, hstate: jax.Array, h: int
) -> tuple[jax.Array, jax.Array]:
    """One decode step. x: [B,1,W]; hstate: [B,W] f32."""
    log_a, gx = _rglru_gates(p, x, h)
    a = jnp.exp(log_a[:, 0])
    new_h = a * hstate + gx[:, 0]
    return new_h.astype(x.dtype)[:, None], new_h


# --------------------------------------------------------------------------
# Griffin recurrent block: in-proj → (gelu gate) ⊙ (conv1d → RG-LRU) → out
# --------------------------------------------------------------------------

def recurrent_block_init(key, cfg: ModelConfig) -> Params:
    dt = cdtype(cfg)
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 5)
    return {
        "wi_gate": dense_init(ks[0], (d, w), d, dt),
        "wi_x": dense_init(ks[1], (d, w), d, dt),
        "conv_w": dense_init(ks[2], (cfg.conv_width, w), cfg.conv_width, dt),
        "conv_b": jnp.zeros((w,), dt),
        "lru": rglru_core_init(ks[3], cfg),
        "wo": dense_init(ks[4], (w, d), w, dt),
    }


def _causal_conv(p: Params, x: jax.Array, width: int) -> jax.Array:
    """Depthwise causal conv1d. x: [B,S,W]."""
    pads = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pads[:, i : i + x.shape[1], :] * p["conv_w"][i]
        for i in range(width)
    )
    return out + p["conv_b"]


def recurrent_block_apply(
    cfg: ModelConfig, p: Params, x: jax.Array
) -> jax.Array:
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["wi_gate"]),
                       approximate=True)
    u = jnp.einsum("bsd,dw->bsw", x, p["wi_x"])
    u = _causal_conv(p, u, cfg.conv_width)
    u = rglru_scan(p["lru"], u, cfg.n_heads)
    return jnp.einsum("bsw,wd->bsd", gate * u, p["wo"])


def recurrent_cache_init(cfg: ModelConfig, batch: int) -> dict:
    dt = cdtype(cfg)
    w = cfg.lru_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dt),
    }


def recurrent_block_step(
    cfg: ModelConfig, p: Params, x: jax.Array, cache: dict
) -> tuple[jax.Array, dict]:
    """One decode step. x: [B,1,D]."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["wi_gate"]),
                       approximate=True)
    u = jnp.einsum("bsd,dw->bsw", x, p["wi_x"])          # [B,1,W]
    hist = jnp.concatenate([cache["conv"], u], axis=1)   # [B,cw,W]
    conv = (
        jnp.einsum("bcw,cw->bw", hist, p["conv_w"]) + p["conv_b"]
    )[:, None]
    y, h = rglru_step(p["lru"], conv, cache["h"], cfg.n_heads)
    out = jnp.einsum("bsw,wd->bsd", gate * y, p["wo"])
    return out, {"h": h, "conv": hist[:, 1:]}


# --------------------------------------------------------------------------
# RWKV-6 time mix (WKV6) + channel mix
# --------------------------------------------------------------------------

def rwkv_time_mix_init(key, cfg: ModelConfig) -> Params:
    dt = cdtype(cfg)
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    ks = jax.random.split(key, 10)
    lora = 32
    return {
        # data-dependent token-shift (ddlerp) lora: 5 mixes (r,k,v,w,g)
        "mu_base": jnp.zeros((d,), dt) + 0.5,
        "mu": jnp.zeros((5, d), dt) + 0.5,
        "ts_w1": dense_init(ks[0], (d, 5 * lora), d, dt),
        "ts_w2": dense_init(ks[1], (5, lora, d), lora, dt),
        "wr": dense_init(ks[2], (d, d), d, dt),
        "wk": dense_init(ks[3], (d, d), d, dt),
        "wv": dense_init(ks[4], (d, d), d, dt),
        "wg": dense_init(ks[5], (d, d), d, dt),
        # decay lora
        "w_base": jnp.zeros((d,), jnp.float32) - 6.0,
        "w_lora1": dense_init(ks[6], (d, 64), d, dt),
        "w_lora2": dense_init(ks[7], (64, d), 64, dt),
        "u": dense_init(ks[8], (h, hd), hd, jnp.float32),  # bonus
        "wo": dense_init(ks[9], (d, d), d, dt),
        "ln_x_scale": jnp.ones((d,), jnp.float32),
        "ln_x_bias": jnp.zeros((d,), jnp.float32),
    }


def _ddlerp(p: Params, x: jax.Array, x_prev: jax.Array):
    """RWKV6 data-dependent token shift → the 5 mixed inputs (r,k,v,w,g)."""
    d = x.shape[-1]
    lora = p["ts_w1"].shape[1] // 5
    base = x + (x_prev - x) * p["mu_base"]
    tshift = jnp.tanh(jnp.einsum("bsd,dl->bsl", base, p["ts_w1"]))
    tshift = tshift.reshape(*tshift.shape[:-1], 5, lora)
    delta = jnp.einsum("bsnl,nld->bsnd", tshift, p["ts_w2"])  # [B,S,5,D]
    mixed = x[..., None, :] + (x_prev[..., None, :] - x[..., None, :]) * (
        p["mu"] + delta
    )
    return [mixed[..., i, :] for i in range(5)]


def _wkv_inputs(cfg: ModelConfig, p: Params, x, x_prev):
    d = x.shape[-1]
    hd = cfg.rwkv_head_dim
    h = d // hd
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev)
    r = jnp.einsum("bsd,de->bse", xr, p["wr"])
    k = jnp.einsum("bsd,de->bse", xk, p["wk"])
    v = jnp.einsum("bsd,de->bse", xv, p["wv"])
    g = jnp.einsum("bsd,de->bse", xg, p["wg"])
    w_log = p["w_base"] + jnp.einsum(
        "bsl,ld->bsd",
        jnp.tanh(jnp.einsum("bsd,dl->bsl", xw, p["w_lora1"])),
        p["w_lora2"],
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log))                    # decay in (0,1), f32
    shp = x.shape[:2] + (h, hd)
    return (
        r.reshape(shp), k.reshape(shp), v.reshape(shp),
        w.reshape(shp), g,
    )


def _groupnorm_heads(p: Params, y: jax.Array, h: int, eps: float = 64e-5):
    """Per-head groupnorm on [B,S,H,hd] → [B,S,D]."""
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + eps)
    b, s = y.shape[:2]
    yn = yn.reshape(b, s, -1)
    return yn * p["ln_x_scale"] + p["ln_x_bias"]


def rwkv_time_mix_apply(
    cfg: ModelConfig, p: Params, x: jax.Array,
) -> jax.Array:
    """Full-sequence WKV6 (sequential scan reference). x: [B,S,D]."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, w, g = _wkv_inputs(cfg, p, x, x_prev)
    u = p["u"]                                       # [H, hd]

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                     # [B,H,hd] each
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)   # f32
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    S0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    seq = (
        r.transpose(1, 0, 2, 3).astype(jnp.float32),
        k.transpose(1, 0, 2, 3).astype(jnp.float32),
        v.transpose(1, 0, 2, 3).astype(jnp.float32),
        w.transpose(1, 0, 2, 3),
    )
    _, ys = jax.lax.scan(step, S0, seq)              # [S,B,H,hd]
    y = ys.transpose(1, 0, 2, 3)
    y = _groupnorm_heads(p, y, h).astype(x.dtype)
    return jnp.einsum("bsd,de->bse", y * jax.nn.silu(g), p["wo"])


def rwkv_cache_init(cfg: ModelConfig, batch: int) -> dict:
    dt = cdtype(cfg)
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    return {
        "S": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "x_prev_tm": jnp.zeros((batch, d), dt),
        "x_prev_cm": jnp.zeros((batch, d), dt),
    }


def rwkv_time_mix_step(
    cfg: ModelConfig, p: Params, x: jax.Array, cache: dict
) -> tuple[jax.Array, dict]:
    """One decode step. x: [B,1,D]."""
    b, _, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    x_prev = cache["x_prev_tm"][:, None]
    r, k, v, w, g = _wkv_inputs(cfg, p, x, x_prev)
    S = cache["S"]
    u = p["u"]
    r1, k1, v1, w1 = (z[:, 0].astype(jnp.float32) for z in (r, k, v, w))
    kv = jnp.einsum("bhk,bhv->bhkv", k1, v1)
    y = jnp.einsum("bhk,bhkv->bhv", r1, S + u[None, :, :, None] * kv)
    S = w1[..., None] * S + kv
    y = _groupnorm_heads(p, y[:, None], h).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y * jax.nn.silu(g), p["wo"])
    return out, {
        "S": S,
        "x_prev_tm": x[:, 0],
        "x_prev_cm": cache["x_prev_cm"],
    }


# ---- RWKV channel mix -------------------------------------------------------

def rwkv_channel_mix_init(key, cfg: ModelConfig) -> Params:
    dt = cdtype(cfg)
    d, m = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((d,), dt) + 0.5,
        "mu_r": jnp.zeros((d,), dt) + 0.5,
        "wk": dense_init(ks[0], (d, m), d, dt),
        "wv": dense_init(ks[1], (m, d), m, dt),
        "wr": dense_init(ks[2], (d, d), d, dt),
    }


def _channel_mix(cfg: ModelConfig, p: Params, x, x_prev):
    xk = x + (x_prev - x) * p["mu_k"]
    xr = x + (x_prev - x) * p["mu_r"]
    k = jnp.einsum("bsd,dm->bsm", xk, p["wk"])
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsm,md->bsd", k, p["wv"])
    return jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"])) * kv


def rwkv_channel_mix_apply(cfg: ModelConfig, p: Params, x: jax.Array):
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return _channel_mix(cfg, p, x, x_prev)


def rwkv_channel_mix_step(
    cfg: ModelConfig, p: Params, x: jax.Array, cache: dict
) -> tuple[jax.Array, dict]:
    y = _channel_mix(cfg, p, x, cache["x_prev_cm"][:, None])
    return y, dict(cache, x_prev_cm=x[:, 0])
