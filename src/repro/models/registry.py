"""Model facade: one uniform (init / apply / prefill / decode) API over all
assigned families (decoder-only dense/MoE/hybrid/rwkv and encoder-decoder).

Batch dict conventions:
- decoder-only: ``{"tokens": [B,S] i32}`` (+ ``"mrope_pos": [3,B,S]`` for the
  VLM backbone)
- encoder-decoder: ``{"frames": [B,T,D] (stub frontend output),
  "tokens": [B,S] i32 (decoder side)}``
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import encdec as _encdec
from repro.models import lm as _lm
from repro.models.common import Params


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---------------- init ----------------

    def init(self, key) -> Params:
        if self.cfg.is_encdec:
            return _encdec.encdec_init(key, self.cfg)
        return _lm.lm_init(key, self.cfg)

    def abstract_params(self, key=None) -> Any:
        """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
        key = key if key is not None else jax.random.key(0)
        return jax.eval_shape(self.init, key)

    # ---------------- training forward ----------------

    def apply(
        self, params: Params, batch: dict, remat: str = "none",
        unroll: bool = False,
    ) -> tuple[jax.Array, jax.Array]:
        """Returns (logits [B,S,V] f32, moe_aux)."""
        if self.cfg.is_encdec:
            return _encdec.encdec_apply(
                params, self.cfg, batch["frames"], batch["tokens"],
                remat=remat, unroll=unroll,
            )
        return _lm.lm_apply(
            params,
            self.cfg,
            batch["tokens"],
            mrope_pos=batch.get("mrope_pos"),
            remat=remat,
            unroll=unroll,
        )

    # ---------------- serving ----------------

    def init_cache(self, batch: int, max_seq: int) -> dict:
        if self.cfg.is_encdec:
            return _encdec.encdec_cache_init(self.cfg, batch, max_seq)
        return _lm.lm_cache_init(self.cfg, batch, max_seq)

    def abstract_cache(self, batch: int, max_seq: int) -> Any:
        return jax.eval_shape(
            lambda: self.init_cache(batch, max_seq)
        )

    def prefill(
        self, params: Params, cache: dict, batch: dict,
        unroll: bool = False,
    ) -> tuple[jax.Array, dict]:
        if self.cfg.is_encdec:
            return _encdec.encdec_prefill(
                params, self.cfg, cache, batch["frames"], batch["tokens"],
                unroll=unroll,
            )
        return _lm.lm_prefill(
            params, self.cfg, cache, batch["tokens"],
            mrope_pos=batch.get("mrope_pos"), unroll=unroll,
        )

    def decode(
        self,
        params: Params,
        cache: dict,
        tokens: jax.Array,   # [B]
        pos: jax.Array,      # [B]
        mrope_pos: jax.Array | None = None,
        unroll: bool = False,
    ) -> tuple[jax.Array, dict]:
        if self.cfg.is_encdec:
            return _encdec.encdec_decode(
                params, self.cfg, cache, tokens, pos, unroll=unroll
            )
        return _lm.lm_decode(
            params, self.cfg, cache, tokens, pos, mrope_pos=mrope_pos,
            unroll=unroll,
        )


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
