"""Decoder-only LM assembly: scanned layer stacks for every assigned family.

The stack is split into three segments so that ``lax.scan`` bodies stay
homogeneous (critical for compile time at 42–62 layers on a 512-way mesh):

- **head**: leading layers that differ from the steady state (deepseek-v2's
  first dense layer), applied unscanned;
- **scanned**: ``n_blocks`` repetitions of ``cfg.layer_pattern`` with stacked
  params ``[n_blocks, ...]``;
- **tail**: remainder layers when ``n_layers`` is not a multiple of the
  pattern (recurrentgemma: 26 = 8×(R,R,L) + R,R), applied unscanned.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.blocks import (
    block_apply,
    block_cache_init,
    block_decode,
    block_init,
    block_prefill,
)
from repro.models.common import (
    Params,
    embed_tokens,
    embedding_init,
    dense_init,
    cdtype,
    logits_from_hidden,
    norm,
    norm_init,
)
from repro.sharding.ctx import constrain


class StackPlan(NamedTuple):
    head: tuple[tuple[str, bool, int | None], ...]  # (kind, moe, d_ff)
    n_blocks: int
    pattern: tuple[tuple[str, bool], ...]           # (kind, moe) per position
    tail: tuple[tuple[str, bool], ...]


def stack_plan(cfg: ModelConfig) -> StackPlan:
    head = []
    for i in range(cfg.first_dense_layers):
        head.append((cfg.layer_kind(i), False, cfg.dense_d_ff or cfg.d_ff))
    rest = cfg.n_layers - len(head)
    plen = cfg.pattern_len
    n_blocks = rest // plen
    moe = cfg.n_experts > 0
    pattern = tuple(
        (cfg.layer_kind(len(head) + j), moe) for j in range(plen)
    )
    tail = tuple(
        (cfg.layer_kind(len(head) + n_blocks * plen + j), moe)
        for j in range(rest - n_blocks * plen)
    )
    return StackPlan(tuple(head), n_blocks, pattern, tail)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def lm_init(key, cfg: ModelConfig) -> Params:
    plan = stack_plan(cfg)
    ks = jax.random.split(key, 6)
    params: Params = {"embed": embedding_init(ks[0], cfg)}

    def group_init(k):
        kk = jax.random.split(k, len(plan.pattern))
        return {
            f"l{j}": block_init(kk[j], cfg, kind, moe)
            for j, (kind, moe) in enumerate(plan.pattern)
        }

    if plan.n_blocks > 0:
        params["blocks"] = jax.vmap(group_init)(
            jax.random.split(ks[1], plan.n_blocks)
        )
    if plan.head:
        hk = jax.random.split(ks[2], len(plan.head))
        params["head_layers"] = [
            block_init(hk[i], cfg, kind, moe, d_ff=d_ff)
            for i, (kind, moe, d_ff) in enumerate(plan.head)
        ]
    if plan.tail:
        tk = jax.random.split(ks[3], len(plan.tail))
        params["tail_layers"] = [
            block_init(tk[i], cfg, kind, moe)
            for i, (kind, moe) in enumerate(plan.tail)
        ]
    params["final_norm"] = norm_init(cfg, cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": dense_init(
                ks[4], (cfg.d_model, cfg.vocab_size), cfg.d_model, cdtype(cfg)
            )
        }
    return params


# --------------------------------------------------------------------------
# full-sequence forward (train / prefill compute)
# --------------------------------------------------------------------------

def lm_apply(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,                  # [B, S] int32 (or [B,S,D] embeddings)
    positions: jax.Array | None = None,
    mrope_pos: jax.Array | None = None,
    remat: str = "none",
    inputs_embeds: jax.Array | None = None,
    unroll: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (logits [B,S,V] f32, moe_aux scalar).

    ``unroll=True`` replaces the layer-stack ``lax.scan`` with a python loop
    (used by the dry-run coster: scan bodies are invisible to HLO cost
    analysis trip counts)."""
    plan = stack_plan(cfg)
    if inputs_embeds is not None:
        x = inputs_embeds
    else:
        x = embed_tokens(cfg, params["embed"], tokens)
    x = constrain(x, "dp", None, None)
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    aux = jnp.zeros((), jnp.float32)

    def run_block(p, kind, moe, x):
        x, a = block_apply(cfg, p, kind, moe, x, positions, mrope_pos)
        return constrain(x, "dp", None, None), a

    for i, (kind, moe, _) in enumerate(plan.head):
        x, a = run_block(params["head_layers"][i], kind, moe, x)
        aux = aux + a

    if plan.n_blocks > 0:
        def group(x, bp):
            a_sum = jnp.zeros((), jnp.float32)
            for j, (kind, moe) in enumerate(plan.pattern):
                x, a = run_block(bp[f"l{j}"], kind, moe, x)
                a_sum = a_sum + a
            return x, a_sum

        if remat == "full":
            group = jax.checkpoint(group)
        elif remat == "dots":
            group = jax.checkpoint(
                group,
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            )

        if unroll:
            for i in range(plan.n_blocks):
                bp = jax.tree.map(lambda a, i=i: a[i], params["blocks"])
                x, a = group(x, bp)
                aux = aux + a
        else:
            def body(carry, bp):
                x, aux = carry
                x, a = group(x, bp)
                return (x, aux + a), None

            (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"])

    for i, (kind, moe) in enumerate(plan.tail):
        x, a = run_block(params["tail_layers"][i], kind, moe, x)
        aux = aux + a

    x = norm(cfg, params["final_norm"], x)
    logits = logits_from_hidden(
        cfg, params["embed"], params.get("lm_head"), x
    )
    return logits, aux


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------

def lm_cache_init(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    plan = stack_plan(cfg)
    cache: dict[str, Any] = {}
    if plan.head:
        cache["head"] = [
            block_cache_init(cfg, kind, batch, max_seq)
            for kind, moe, _ in plan.head
        ]
    if plan.n_blocks > 0:
        def one(_):
            return {
                f"l{j}": block_cache_init(cfg, kind, batch, max_seq)
                for j, (kind, _) in enumerate(plan.pattern)
            }

        cache["blocks"] = jax.vmap(one)(jnp.arange(plan.n_blocks))
    if plan.tail:
        cache["tail"] = [
            block_cache_init(cfg, kind, batch, max_seq)
            for kind, _ in plan.tail
        ]
    return cache


# --------------------------------------------------------------------------
# prefill / decode
# --------------------------------------------------------------------------

def lm_prefill(
    params: Params,
    cfg: ModelConfig,
    cache: dict,
    tokens: jax.Array,
    positions: jax.Array | None = None,
    mrope_pos: jax.Array | None = None,
    inputs_embeds: jax.Array | None = None,
    unroll: bool = False,
) -> tuple[jax.Array, dict]:
    """Full-sequence prefill; returns (last-position logits [B,V], cache)."""
    plan = stack_plan(cfg)
    x = (
        inputs_embeds
        if inputs_embeds is not None
        else embed_tokens(cfg, params["embed"], tokens)
    )
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    new_cache: dict[str, Any] = {}

    if plan.head:
        hc = []
        for i, (kind, moe, _) in enumerate(plan.head):
            x, c = block_prefill(
                cfg, params["head_layers"][i], kind, moe, x, positions,
                cache["head"][i], mrope_pos,
            )
            hc.append(c)
        new_cache["head"] = hc

    if plan.n_blocks > 0:
        def body(x, xs):
            bp, bc = xs
            cs = {}
            for j, (kind, moe) in enumerate(plan.pattern):
                x, c = block_prefill(
                    cfg, bp[f"l{j}"], kind, moe, x, positions,
                    bc[f"l{j}"], mrope_pos,
                )
                cs[f"l{j}"] = c
            return x, cs

        if unroll:
            outs = []
            for i in range(plan.n_blocks):
                xs_i = jax.tree.map(
                    lambda a, i=i: a[i], (params["blocks"], cache["blocks"])
                )
                x, cs = body(x, xs_i)
                outs.append(cs)
            new_cache["blocks"] = jax.tree.map(
                lambda *leaves: jnp.stack(leaves), *outs
            )
        else:
            x, new_cache["blocks"] = jax.lax.scan(
                body, x, (params["blocks"], cache["blocks"])
            )

    if plan.tail:
        tc = []
        for i, (kind, moe) in enumerate(plan.tail):
            x, c = block_prefill(
                cfg, params["tail_layers"][i], kind, moe, x, positions,
                cache["tail"][i], mrope_pos,
            )
            tc.append(c)
        new_cache["tail"] = tc

    x = norm(cfg, params["final_norm"], x[:, -1:])
    logits = logits_from_hidden(cfg, params["embed"], params.get("lm_head"), x)
    return logits[:, 0], new_cache


def lm_decode(
    params: Params,
    cfg: ModelConfig,
    cache: dict,
    tokens: jax.Array,       # [B] int32
    pos: jax.Array,          # [B] int32 current position
    mrope_pos: jax.Array | None = None,
    unroll: bool = False,
) -> tuple[jax.Array, dict]:
    """One decode step; returns (logits [B, V] f32, cache)."""
    plan = stack_plan(cfg)
    x = embed_tokens(cfg, params["embed"], tokens[:, None])
    new_cache: dict[str, Any] = {}

    if plan.head:
        hc = []
        for i, (kind, moe, _) in enumerate(plan.head):
            x, c = block_decode(
                cfg, params["head_layers"][i], kind, moe, x, pos,
                cache["head"][i], mrope_pos,
            )
            hc.append(c)
        new_cache["head"] = hc

    if plan.n_blocks > 0:
        def body(x, xs):
            bp, bc = xs
            cs = {}
            for j, (kind, moe) in enumerate(plan.pattern):
                x, c = block_decode(
                    cfg, bp[f"l{j}"], kind, moe, x, pos,
                    bc[f"l{j}"], mrope_pos,
                )
                cs[f"l{j}"] = c
            return x, cs

        if unroll:
            outs = []
            for i in range(plan.n_blocks):
                xs_i = jax.tree.map(
                    lambda a, i=i: a[i], (params["blocks"], cache["blocks"])
                )
                x, cs = body(x, xs_i)
                outs.append(cs)
            new_cache["blocks"] = jax.tree.map(
                lambda *leaves: jnp.stack(leaves), *outs
            )
        else:
            x, new_cache["blocks"] = jax.lax.scan(
                body, x, (params["blocks"], cache["blocks"])
            )

    if plan.tail:
        tc = []
        for i, (kind, moe) in enumerate(plan.tail):
            x, c = block_decode(
                cfg, params["tail_layers"][i], kind, moe, x, pos,
                cache["tail"][i], mrope_pos,
            )
            tc.append(c)
        new_cache["tail"] = tc

    x = norm(cfg, params["final_norm"], x)
    logits = logits_from_hidden(cfg, params["embed"], params.get("lm_head"), x)
    return logits[:, 0], new_cache
