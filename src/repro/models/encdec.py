"""Encoder-decoder (Whisper large-v3 backbone).

Per the assignment the mel/conv frontend is a STUB: the encoder consumes
precomputed frame embeddings ``[B, T, d_model]`` (what the conv stack would
emit). Positions are fixed sinusoidal on both sides (the learned decoder
table is an inessential detail at 32k-context shapes — noted in DESIGN.md).

Encoder: non-causal self-attention blocks (scanned).
Decoder: causal self-attention + cross-attention + FFN blocks (scanned),
with KV caches for generation; cross-K/V computed once at prefill.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.attention import (
    attn_apply,
    attn_decode,
    attn_init,
    attn_prefill,
    cross_attn_apply,
    cross_attn_init,
    cross_attn_kv,
    kv_cache_init,
)
from repro.models.common import (
    Params,
    cdtype,
    dense_init,
    embed_tokens,
    embedding_init,
    logits_from_hidden,
    norm,
    norm_init,
    sinusoidal_positions,
)
from repro.models.ffn import ffn_apply, ffn_init
from repro.sharding.ctx import constrain


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _enc_block_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": norm_init(cfg, cfg.d_model),
        "attn": attn_init(k1, cfg),
        "norm2": norm_init(cfg, cfg.d_model),
        "ffn": ffn_init(k2, cfg),
    }


def _dec_block_init(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": norm_init(cfg, cfg.d_model),
        "self_attn": attn_init(k1, cfg),
        "norm_x": norm_init(cfg, cfg.d_model),
        "cross_attn": cross_attn_init(k2, cfg),
        "norm2": norm_init(cfg, cfg.d_model),
        "ffn": ffn_init(k3, cfg),
    }


def encdec_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)
    return {
        "frame_proj": dense_init(
            ks[0], (cfg.d_model, cfg.d_model), cfg.d_model, cdtype(cfg)
        ),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg))(
            jax.random.split(ks[1], cfg.n_enc_layers)
        ),
        "enc_norm": norm_init(cfg, cfg.d_model),
        "embed": embedding_init(ks[2], cfg),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg))(
            jax.random.split(ks[3], cfg.n_layers)
        ),
        "final_norm": norm_init(cfg, cfg.d_model),
    }


# --------------------------------------------------------------------------
# encoder
# --------------------------------------------------------------------------

def encode(params: Params, cfg: ModelConfig, frames: jax.Array,
           unroll: bool = False, remat: str = "none") -> jax.Array:
    """frames: [B, T, D] stub frontend output → encoder states [B, T, D]."""
    b, t, _ = frames.shape
    x = jnp.einsum("btd,de->bte", frames, params["frame_proj"])
    x = x + sinusoidal_positions(t, cfg.d_model).astype(x.dtype)[None]
    x = constrain(x, "dp", None, None)
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))

    def group(x, bp):
        h = norm(cfg, bp["norm1"], x)
        x = x + attn_apply(cfg, bp["attn"], h, positions, "global",
                           causal=False)
        h = norm(cfg, bp["norm2"], x)
        x = x + ffn_apply(cfg, bp["ffn"], h)
        return constrain(x, "dp", None, None)

    if remat in ("full", "dots"):
        group = jax.checkpoint(group)

    def body(x, bp):
        return group(x, bp), None

    if unroll:
        for i in range(cfg.n_enc_layers):
            x, _ = body(x, jax.tree.map(lambda a, i=i: a[i],
                                        params["enc_blocks"]))
    else:
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return norm(cfg, params["enc_norm"], x)


# --------------------------------------------------------------------------
# decoder (teacher-forced full-seq — training)
# --------------------------------------------------------------------------

def encdec_apply(
    params: Params,
    cfg: ModelConfig,
    frames: jax.Array,        # [B, T, D]
    dec_tokens: jax.Array,    # [B, S]
    remat: str = "none",
    unroll: bool = False,
) -> tuple[jax.Array, jax.Array]:
    enc = encode(params, cfg, frames, unroll=unroll, remat=remat)
    b, s = dec_tokens.shape
    x = embed_tokens(cfg, params["embed"], dec_tokens)
    x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)[None]
    x = constrain(x, "dp", None, None)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def group(x, bp):
        h = norm(cfg, bp["norm1"], x)
        x = x + attn_apply(cfg, bp["self_attn"], h, positions, "global")
        h = norm(cfg, bp["norm_x"], x)
        kv = cross_attn_kv(cfg, bp["cross_attn"], enc)
        x = x + cross_attn_apply(cfg, bp["cross_attn"], h, kv)
        h = norm(cfg, bp["norm2"], x)
        x = x + ffn_apply(cfg, bp["ffn"], h)
        return constrain(x, "dp", None, None)

    if remat in ("full", "dots"):
        group = jax.checkpoint(group)

    if unroll:
        for i in range(cfg.n_layers):
            x = group(x, jax.tree.map(lambda a, i=i: a[i],
                                      params["dec_blocks"]))
    else:
        def body(x, bp):
            return group(x, bp), None

        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = norm(cfg, params["final_norm"], x)
    logits = logits_from_hidden(cfg, params["embed"], None, x)
    return logits, jnp.zeros((), jnp.float32)


# --------------------------------------------------------------------------
# serving: prefill computes encoder states + cross-KV; decode steps the
# decoder against both caches
# --------------------------------------------------------------------------

def encdec_cache_init(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    n = cfg.n_layers
    self_cache = jax.vmap(
        lambda _: kv_cache_init(cfg, batch, max_seq, "global")
    )(jnp.arange(n))
    dt = cdtype(cfg)
    cross_kv = {
        "k": jnp.zeros((n, batch, cfg.enc_ctx, cfg.n_heads, cfg.head_dim), dt),
        "v": jnp.zeros((n, batch, cfg.enc_ctx, cfg.n_heads, cfg.head_dim), dt),
    }
    return {"self": self_cache, "cross": cross_kv}


def encdec_prefill(
    params: Params,
    cfg: ModelConfig,
    cache: dict,
    frames: jax.Array,       # [B, enc_ctx, D]
    dec_tokens: jax.Array,   # [B, S0] decoder prompt
    unroll: bool = False,
) -> tuple[jax.Array, dict]:
    enc = encode(params, cfg, frames, unroll=unroll)
    b, s = dec_tokens.shape
    x = embed_tokens(cfg, params["embed"], dec_tokens)
    x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, xs):
        bp, sc = xs
        h = norm(cfg, bp["norm1"], x)
        sub, sc = attn_prefill(
            cfg, bp["self_attn"], h, positions, sc, "global"
        )
        x = x + sub
        kv = cross_attn_kv(cfg, bp["cross_attn"], enc)
        h = norm(cfg, bp["norm_x"], x)
        x = x + cross_attn_apply(cfg, bp["cross_attn"], h, kv)
        h = norm(cfg, bp["norm2"], x)
        x = x + ffn_apply(cfg, bp["ffn"], h)
        return x, (sc, {"k": kv[0], "v": kv[1]})

    if unroll:
        outs = []
        for i in range(cfg.n_layers):
            xs_i = jax.tree.map(lambda a, i=i: a[i],
                                (params["dec_blocks"], cache["self"]))
            x, out_i = body(x, xs_i)
            outs.append(out_i)
        self_cache, cross_kv = jax.tree.map(
            lambda *ls: jnp.stack(ls), *outs
        )
    else:
        x, (self_cache, cross_kv) = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["self"])
        )
    x = norm(cfg, params["final_norm"], x[:, -1:])
    logits = logits_from_hidden(cfg, params["embed"], None, x)
    return logits[:, 0], {"self": self_cache, "cross": cross_kv}


def _sinusoidal_at(pos: jax.Array, d: int) -> jax.Array:
    """Sinusoidal embedding at integer positions ``pos`` [B] → [B, d]."""
    half = d // 2
    scale = jnp.exp(
        -jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1)
    )
    ang = pos.astype(jnp.float32)[:, None] * scale[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def encdec_decode(
    params: Params,
    cfg: ModelConfig,
    cache: dict,
    tokens: jax.Array,   # [B]
    pos: jax.Array,      # [B]
    unroll: bool = False,
) -> tuple[jax.Array, dict]:
    b = tokens.shape[0]
    x = embed_tokens(cfg, params["embed"], tokens[:, None])
    x = x + _sinusoidal_at(pos, cfg.d_model)[:, None].astype(x.dtype)

    def body(x, xs):
        bp, sc, ckv = xs
        h = norm(cfg, bp["norm1"], x)
        sub, sc = attn_decode(cfg, bp["self_attn"], h, pos, sc, "global")
        x = x + sub
        h = norm(cfg, bp["norm_x"], x)
        x = x + cross_attn_apply(
            cfg, bp["cross_attn"], h, (ckv["k"], ckv["v"])
        )
        h = norm(cfg, bp["norm2"], x)
        x = x + ffn_apply(cfg, bp["ffn"], h)
        return x, sc

    if unroll:
        outs = []
        for i in range(cfg.n_layers):
            xs_i = jax.tree.map(
                lambda a, i=i: a[i],
                (params["dec_blocks"], cache["self"], cache["cross"]),
            )
            x, sc_i = body(x, xs_i)
            outs.append(sc_i)
        self_cache = jax.tree.map(lambda *ls: jnp.stack(ls), *outs)
    else:
        x, self_cache = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["self"], cache["cross"])
        )
    x = norm(cfg, params["final_norm"], x)
    logits = logits_from_hidden(cfg, params["embed"], None, x)
    return logits[:, 0], {"self": self_cache, "cross": cache["cross"]}
