"""Modality frontends — STUBS per the assignment.

``[audio]`` / ``[vlm]`` architectures specify the transformer BACKBONE only;
``input_specs()`` provides precomputed frame/patch embeddings. These helpers
generate those stand-ins for smoke tests and document the contract:

- audio  (whisper): frames ``[B, T, d_model]`` — what the mel+conv stack
  would emit after its stride-2 downsampling.
- vision (qwen2-vl): a merged token stream ``[B, S]`` plus M-RoPE position
  ids ``[3, B, S]`` — what the ViT patch encoder + merger would emit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig


def audio_frames_stub(key, cfg: ModelConfig, batch: int, t: int) -> jax.Array:
    return jax.random.normal(key, (batch, t, cfg.d_model), jnp.dtype(cfg.dtype))


def vision_stream_stub(
    key, cfg: ModelConfig, batch: int, s: int, image_frac: float = 0.25
) -> tuple[jax.Array, jax.Array]:
    """Tokens + M-RoPE positions for a text/[image]/text stream.

    The leading ``image_frac`` of the stream stands for a merged image patch
    grid: its (t,h,w) position ids follow the grid; the text remainder has
    all three streams equal (Qwen2-VL convention).
    """
    k1, _ = jax.random.split(key)
    tokens = jax.random.randint(k1, (batch, s), 0, cfg.vocab_size)
    n_img = int(s * image_frac)
    side = max(int(n_img**0.5), 1)
    n_img = side * side
    idx = jnp.arange(n_img)
    img_t = jnp.zeros((n_img,), jnp.int32)
    img_h = (idx // side).astype(jnp.int32)
    img_w = (idx % side).astype(jnp.int32)
    text_pos = jnp.arange(s - n_img, dtype=jnp.int32) + side  # after grid
    t_stream = jnp.concatenate([img_t, text_pos])
    h_stream = jnp.concatenate([img_h, text_pos])
    w_stream = jnp.concatenate([img_w, text_pos])
    mrope = jnp.stack([t_stream, h_stream, w_stream])          # [3, S]
    mrope = jnp.broadcast_to(mrope[:, None, :], (3, batch, s))
    return tokens, mrope
