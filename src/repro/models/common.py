"""Shared model components: norms, RoPE/M-RoPE, embeddings, initializers.

Pure-functional style: parameters are nested dicts of arrays; every module is
(init, apply) function pairs. Stacked (scanned) layers carry a leading
``[n_blocks, ...]`` axis on every leaf.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.sharding.ctx import constrain

Params = dict[str, Any]


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size: int, dtype) -> jax.Array:
    """Truncated-normal fan-in init (LeCun-style)."""
    std = in_axis_size ** -0.5
    return (
        jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std
    ).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (
        jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * 0.02
    ).astype(dtype)


# --------------------------------------------------------------------------
# norms — computed in f32 regardless of activation dtype
# --------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + scale): zero-init scale == identity
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


def layernorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def norm_init(cfg: ModelConfig, d: int) -> Params:
    if cfg.norm_kind == "layernorm":
        return layernorm_init(d, cdtype(cfg))
    return rmsnorm_init(d, cdtype(cfg))


def norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm_kind == "layernorm":
        return layernorm(p, x, cfg.norm_eps)
    return rmsnorm(p, x, cfg.norm_eps)


# --------------------------------------------------------------------------
# rotary embeddings (+ M-RoPE)
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float
) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] int32. Split-half convention."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # [half]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B,S,half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    theta: float,
    sections: tuple[int, ...],
) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: [B, S, H, D]; positions: [3, B, S] — (temporal, height, width) streams.
    ``sections`` are half-dim section lengths summing to D//2; section ``i``
    takes its angles from position stream ``i``.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)  # [half]
    ang_each = positions[..., None].astype(jnp.float32) * freqs  # [3,B,S,half]
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        parts.append(ang_each[i, :, :, start : start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)  # [B,S,half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal table [n, d] (f32)."""
    half = d // 2
    scale = jnp.exp(
        -jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1)
    )
    pos = jnp.arange(n, dtype=jnp.float32)[:, None] * scale[None, :]
    return jnp.concatenate([jnp.sin(pos), jnp.cos(pos)], axis=1)


# --------------------------------------------------------------------------
# embedding / unembedding
# --------------------------------------------------------------------------

def embedding_init(key, cfg: ModelConfig) -> Params:
    p = {"table": embed_init(key, (cfg.vocab_size, cfg.d_model), cdtype(cfg))}
    return p


def embed_tokens(cfg: ModelConfig, p: Params, tokens: jax.Array) -> jax.Array:
    x = p["table"][tokens]
    if cfg.emb_scale == "sqrt_d":
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    elif cfg.emb_scale == "const12":
        x = x * jnp.asarray(12.0, x.dtype)
    return x


def logits_from_hidden(
    cfg: ModelConfig, embed_params: Params, head: Params | None, x: jax.Array
) -> jax.Array:
    """Final projection to vocabulary, in f32, with optional softcap.

    The vocab axis is padded up to a multiple of the model-axis size
    (Megatron-style vocab-parallel logits) so indivisible vocabularies
    (whisper 51866, minicpm3 73448) still shard; pad columns carry −inf so
    downstream softmax/CE ignore them. The pad is sliced off before return
    only when no mesh is active (tests)."""
    from repro.sharding.ctx import tp_size

    if cfg.tie_embeddings or head is None:
        w = embed_params["table"]  # [V, d]
    else:
        w = head["w"].T            # [V, d]
    v = w.shape[0]
    tp = tp_size()
    pad = (-v) % tp
    if pad:
        w = jnp.concatenate([w, jnp.zeros((pad, w.shape[1]), w.dtype)], 0)
    logits = jnp.einsum(
        "...d,vd->...v", x.astype(jnp.float32), w.astype(jnp.float32)
    )
    if cfg.final_logit_softcap > 0.0:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    if pad:
        neg = jnp.full((pad,), -2.0**30, logits.dtype)
        logits = logits + jnp.concatenate(
            [jnp.zeros((v,), logits.dtype), neg]
        )
    # keep the vocab axis sharded over 'model' — the CE loss consumes sharded
    # logits without ever materializing the full [B,S,V] f32 tensor
    return constrain(logits, "dp", *([None] * (logits.ndim - 2)), "tp")


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(x / cap) if cap > 0.0 else x
