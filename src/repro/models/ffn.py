"""FFN layers: gated-linear-unit dense FFN + capacity-based mixture-of-experts.

The MoE uses scatter-based dispatch (tokens are scattered into per-expert
capacity buffers, experts run as one batched einsum over the expert axis,
outputs gather back) — GShard/Switch semantics without the O(S·E·C) one-hot
dispatch tensors. The expert axis is the expert-parallel shard axis.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.common import Params, cdtype, dense_init
from repro.sharding.ctx import constrain


def _act(kind: str, x: jax.Array) -> jax.Array:
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


# --------------------------------------------------------------------------
# dense GLU FFN
# --------------------------------------------------------------------------

def ffn_init(key, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    dt = cdtype(cfg)
    d = cfg.d_model
    m = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, (d, m), d, dt),
        "wi_up": dense_init(k2, (d, m), d, dt),
        "wo": dense_init(k3, (m, d), m, dt),
    }


def ffn_apply(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    g = _act(cfg.activation, jnp.einsum("bsd,dm->bsm", x, p["wi_gate"]))
    u = jnp.einsum("bsd,dm->bsm", x, p["wi_up"])
    return jnp.einsum("bsm,md->bsd", g * u, p["wo"])


# --------------------------------------------------------------------------
# mixture of experts
# --------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig) -> Params:
    dt = cdtype(cfg)
    d, e, m = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": dense_init(ks[0], (d, e), d, jnp.float32),
        "wi_gate": dense_init(ks[1], (e, d, m), d, dt),
        "wi_up": dense_init(ks[2], (e, d, m), d, dt),
        "wo": dense_init(ks[3], (e, m, d), m, dt),
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = ffn_init(
            ks[4], cfg, d_ff=cfg.moe_d_ff * cfg.n_shared_experts
        )
    return p


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts)
    return max(cap, cfg.top_k)


def moe_apply(
    cfg: ModelConfig, p: Params, x: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Returns (y, aux_loss). x: [B, S, D].

    Dispatch: flatten to T=B·S tokens, route top-k, compute each routed
    token's position within its expert's capacity buffer via a stable sort
    over expert ids, scatter (drop beyond capacity), run experts batched,
    gather + combine.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = moe_capacity(cfg, t)
    xt = x.reshape(t, d)

    logits = jnp.einsum(
        "td,de->te", xt.astype(jnp.float32), p["router"]
    )                                                       # [T, E] f32
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, k)                  # [T, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert's buffer:
    # stable-sort by expert id, then rank = index − start-of-run (cummax)
    flat_e = expert.reshape(-1)                             # [T*k]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    idx = jnp.arange(t * k, dtype=jnp.int32)
    run_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]]
    )
    start_idx = jax.lax.cummax(jnp.where(run_start, idx, 0))
    pos_sorted = idx - start_idx
    ranks = (
        jnp.zeros((t * k,), jnp.int32).at[order].set(pos_sorted).reshape(t, k)
    )

    keep = ranks < cap                                      # capacity dropping
    slot = jnp.where(keep, expert * cap + ranks, e * cap)   # OOB → dropped

    # scatter tokens into expert buffers [E·cap, D] ('drop' mode for OOB).
    # NOTE (§Perf, refuted hypothesis): an expert-major [E, cap, D] scatter
    # with a with_sharding_constraint on the expert axis *increased* wire
    # bytes 1.4× on deepseek-v2 — GSPMD all-gathers the token payload across
    # 'model' before the sharded scatter. The flat scatter + propagation is
    # the measured-better layout; revisit with a shard_map all-to-all.
    buf = jnp.zeros((e * cap, d), x.dtype)
    buf = buf.at[slot.reshape(-1)].add(
        jnp.repeat(xt, k, axis=0) * keep.reshape(-1, 1).astype(x.dtype),
        mode="drop",
    )
    buf = buf.reshape(e, cap, d)

    # batched expert FFN over the (sharded) expert axis
    g = _act(cfg.activation, jnp.einsum("ecd,edm->ecm", buf, p["wi_gate"]))
    u = jnp.einsum("ecd,edm->ecm", buf, p["wi_up"])
    out = jnp.einsum("ecm,emd->ecd", g * u, p["wo"]).reshape(e * cap, d)

    # gather back and combine with gates
    out_pad = jnp.concatenate([out, jnp.zeros((1, d), out.dtype)], axis=0)
    gathered = out_pad[jnp.minimum(slot, e * cap)]          # [T, k, D]
    y = jnp.einsum(
        "tkd,tk->td", gathered, (gate * keep).astype(gathered.dtype)
    ).reshape(b, s, d)

    if cfg.n_shared_experts > 0:
        y = y + ffn_apply(cfg, p["shared"], x)

    # switch-style load-balance aux loss
    me = jnp.mean(probs, axis=0)                            # mean router prob
    one_hot_top1 = jax.nn.one_hot(expert[:, 0], e)
    ce = jnp.mean(one_hot_top1, axis=0)                     # top-1 load frac
    aux = e * jnp.sum(me * ce)
    return y, aux
