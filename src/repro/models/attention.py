"""Attention: GQA with RoPE/M-RoPE, sliding windows, logit softcaps; MLA;
KV caches (ring-buffered for windowed layers); absorbed-MLA decode.

Layouts: activations ``[B, S, D]``; per-head tensors ``[B, S, H, hd]``;
KV caches ``[B, S_cache, K, hd]`` with an entry-position array ``[B, S_cache]``
(−1 = empty). Windowed ("local") layers allocate ``S_cache == window`` and
write decode entries at ``pos % window`` — O(window) memory at any context
length, which is what makes gemma2's local layers and recurrentgemma
long-context-viable.

The pure-jnp paths here are the autodiff/dry-run reference; the Pallas flash
kernel (``repro.kernels.flash_attention``) is the TPU execution path and is
verified against these in tests.
"""

from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Any

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.common import (
    Params,
    apply_mrope,
    apply_rope,
    cdtype,
    dense_init,
    rmsnorm,
    rmsnorm_init,
    softcap,
)

NEG_INF = -2.0**30


# --------------------------------------------------------------------------
# scaled dot-product attention with grouped KV heads (no KV repeat in memory)
# --------------------------------------------------------------------------

def sdpa(
    q: jax.Array,           # [B, Sq, H, D]
    k: jax.Array,           # [B, Sk, K, D]
    v: jax.Array,           # [B, Sk, K, Dv]
    mask: jax.Array | None, # [B, Sq, Sk] bool or None
    scale: float,
    cap: float = 0.0,
) -> jax.Array:
    b, sq, h, d = q.shape
    kheads = k.shape[2]
    g = h // kheads
    qg = q.reshape(b, sq, kheads, g, d)
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32
    )
    scores = scores * scale
    scores = softcap(scores, cap)
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    ctx = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return ctx.reshape(b, sq, h, v.shape[-1])


def causal_mask(sq: int, sk: int, window: int = 0) -> jax.Array:
    """[1, Sq, Sk] bool; queries at positions sk-sq..sk-1."""
    qpos = jnp.arange(sq)[:, None] + (sk - sq)
    kpos = jnp.arange(sk)[None, :]
    m = qpos >= kpos
    if window > 0:
        m &= (qpos - kpos) < window
    return m[None]


# --------------------------------------------------------------------------
# statically-tiled flash attention (pure JAX)
#
# Python-unrolled q×kv tiles with online softmax: bounded VMEM-sized score
# temps, true O(S·window) cost for local layers (fully-masked tiles are
# skipped at TRACE time), and — unlike a lax.scan over tiles — every tile's
# FLOPs/bytes are visible to the compiled-HLO cost analysis the roofline
# reads. On real TPUs the Pallas kernel (repro.kernels.flash_attention)
# replaces this; the tiling logic is deliberately identical.
# --------------------------------------------------------------------------

_FLASH_TILE = 2048


def flash_xla(
    q: jax.Array,            # [B, Sq, H, D]
    k: jax.Array,            # [B, Sk, K, D]
    v: jax.Array,            # [B, Sk, K, Dv]
    *,
    causal: bool,
    window: int,
    scale: float,
    cap: float = 0.0,
    tile_q: int = _FLASH_TILE,
    tile_k: int = _FLASH_TILE,
) -> jax.Array:
    b, sq, h, d = q.shape
    sk = k.shape[1]
    kheads = k.shape[2]
    g = h // kheads
    if sq <= tile_q and sk <= tile_k:
        mask = causal_mask(sq, sk, window) if causal else None
        return sdpa(q, k, v, mask, scale, cap)

    cq = min(tile_q, sq)
    ck = min(tile_k, sk)
    assert sq % cq == 0 and sk % ck == 0, (sq, cq, sk, ck)
    offset = sk - sq  # query absolute position offset
    out_chunks = []
    for iq in range(sq // cq):
        qs, qe = iq * cq, (iq + 1) * cq
        qc = q[:, qs:qe].reshape(b, cq, kheads, g, d)
        m_run = jnp.full((b, kheads, g, cq, 1), NEG_INF, jnp.float32)
        l_run = jnp.zeros((b, kheads, g, cq, 1), jnp.float32)
        acc = jnp.zeros((b, cq, kheads, g, v.shape[-1]), jnp.float32)
        for ik in range(sk // ck):
            ks, ke = ik * ck, (ik + 1) * ck
            if causal and qe - 1 + offset < ks:
                continue  # tile fully in the future
            if window > 0 and qs + offset - (ke - 1) >= window:
                continue  # tile fully outside the window
            kc = k[:, ks:ke]
            vc = v[:, ks:ke]
            s = jnp.einsum(
                "bqkgd,bskd->bkgqs", qc, kc,
                preferred_element_type=jnp.float32,
            ) * scale
            s = softcap(s, cap)
            qpos = jnp.arange(qs, qe)[:, None] + offset
            kpos = jnp.arange(ks, ke)[None, :]
            msk = jnp.ones((cq, ck), bool)
            if causal:
                msk &= qpos >= kpos
            if window > 0:
                msk &= (qpos - kpos) < window
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1, keepdims=True))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new)
            l_run = l_run * alpha + p.sum(axis=-1, keepdims=True)
            acc = acc * alpha.transpose(0, 3, 1, 2, 4) + jnp.einsum(
                "bkgqs,bskd->bqkgd", p.astype(v.dtype), vc
            ).astype(jnp.float32)
            m_run = m_new
        l_safe = jnp.maximum(l_run, 1e-30).transpose(0, 3, 1, 2, 4)
        out_chunks.append((acc / l_safe).astype(q.dtype))
    out = jnp.concatenate(out_chunks, axis=1)
    return out.reshape(b, sq, h, v.shape[-1])


_ATTN_IMPL: ContextVar = ContextVar("attn_impl", default="xla")


@contextlib.contextmanager
def attention_impl(name: str):
    """Select the full-sequence attention execution path ('xla' | 'pallas').

    'pallas' routes every full-seq attention through the TPU flash kernel
    (interpret mode on CPU) — the real-hardware execution path, validated
    against the XLA path in tests. Roofline/dry-run lowering keeps 'xla' so
    FLOPs stay visible to HLO cost analysis (DESIGN.md §6).
    """
    token = _ATTN_IMPL.set(name)
    try:
        yield
    finally:
        _ATTN_IMPL.reset(token)


def full_attention(
    q, k, v, *, causal: bool, window: int, scale: float, cap: float = 0.0
) -> jax.Array:
    """Dispatch: Pallas kernel when selected (the TPU execution path),
    else tiled flash-XLA for long sequences, plain sdpa otherwise."""
    if _ATTN_IMPL.get() == "pallas":
        from repro.kernels import flash_attention as pallas_flash

        bq = min(128, q.shape[1])
        bk = min(128, k.shape[1])
        return pallas_flash(
            q, k, v, causal=causal, window=window, softcap=cap, scale=scale,
            block_q=bq, block_k=bk,
        )
    if q.shape[1] > _FLASH_TILE or k.shape[1] > _FLASH_TILE:
        return flash_xla(
            q, k, v, causal=causal, window=window, scale=scale, cap=cap
        )
    mask = causal_mask(q.shape[1], k.shape[1], window) if causal else None
    return sdpa(q, k, v, mask, scale, cap)


# --------------------------------------------------------------------------
# standard (GQA) attention layer
# --------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig) -> Params:
    dt = cdtype(cfg)
    ks = jax.random.split(key, 4)
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p: Params = {
        "wq": dense_init(ks[0], (d, h, hd), d, dt),
        "wk": dense_init(ks[1], (d, kh, hd), d, dt),
        "wv": dense_init(ks[2], (d, kh, hd), d, dt),
        "wo": dense_init(ks[3], (h, hd, d), h * hd, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dt)
        p["bk"] = jnp.zeros((kh, hd), dt)
        p["bv"] = jnp.zeros((kh, hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dt)
        p["k_norm"] = rmsnorm_init(hd, dt)
    return p


def _qkv(cfg: ModelConfig, p: Params, x: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    return q, k, v


def _rope_qk(cfg: ModelConfig, q, k, positions, mrope_pos):
    if cfg.rope_theta <= 0.0:
        return q, k
    if cfg.mrope_sections and mrope_pos is not None:
        q = apply_mrope(q, mrope_pos, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mrope_pos, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def attn_apply(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,             # [B, S, D]
    positions: jax.Array,     # [B, S]
    kind: str = "global",     # "global" | "local"
    mrope_pos: jax.Array | None = None,
    causal: bool = True,
) -> jax.Array:
    """Full-sequence attention (train / prefill compute)."""
    b, s, _ = x.shape
    q, k, v = _qkv(cfg, p, x)
    q, k = _rope_qk(cfg, q, k, positions, mrope_pos)
    window = cfg.window if kind == "local" else 0
    ctx = full_attention(
        q, k, v, causal=causal, window=window,
        scale=cfg.head_dim**-0.5, cap=cfg.attn_logit_softcap,
    )
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])


# --------------------------------------------------------------------------
# KV cache (standard layers)
# --------------------------------------------------------------------------

def kv_cache_init(
    cfg: ModelConfig, batch: int, max_seq: int, kind: str
) -> dict[str, jax.Array]:
    s = min(max_seq, cfg.window) if (kind == "local" and cfg.window) else max_seq
    dt = cdtype(cfg)
    return {
        "k": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim), dt),
        "pos": jnp.full((batch, s), -1, jnp.int32),
    }


def attn_prefill(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    cache: dict,
    kind: str,
    mrope_pos: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    """Full-seq attention + fill the cache (ring-rolled for local layers)."""
    b, s, _ = x.shape
    q, k, v = _qkv(cfg, p, x)
    q, k = _rope_qk(cfg, q, k, positions, mrope_pos)
    window = cfg.window if kind == "local" else 0
    ctx = full_attention(
        q, k, v, causal=True, window=window,
        scale=cfg.head_dim**-0.5, cap=cfg.attn_logit_softcap,
    )
    y = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])

    s_cache = cache["k"].shape[1]
    if s <= s_cache:
        cache = {
            "k": jax.lax.dynamic_update_slice(
                cache["k"], k, (0, 0, 0, 0)
            ),
            "v": jax.lax.dynamic_update_slice(
                cache["v"], v, (0, 0, 0, 0)
            ),
            "pos": jax.lax.dynamic_update_slice(
                cache["pos"], positions.astype(jnp.int32), (0, 0)
            ),
        }
    else:
        # keep only the last s_cache entries, at slot = pos % s_cache
        shift = s % s_cache
        cache = {
            "k": jnp.roll(k[:, s - s_cache :], shift, axis=1),
            "v": jnp.roll(v[:, s - s_cache :], shift, axis=1),
            "pos": jnp.roll(
                positions[:, s - s_cache :].astype(jnp.int32), shift, axis=1
            ),
        }
    return y, cache


def attn_decode(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,            # [B, 1, D]
    pos: jax.Array,          # [B] current position
    cache: dict,
    kind: str,
    mrope_pos: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    """Single-token decode against the cache."""
    b = x.shape[0]
    q, k, v = _qkv(cfg, p, x)
    q, k = _rope_qk(cfg, q, k, pos[:, None], mrope_pos)

    s_cache = cache["k"].shape[1]
    slot = (pos % s_cache).astype(jnp.int32)
    bi = jnp.arange(b)
    ck = cache["k"].at[bi, slot].set(k[:, 0])
    cv = cache["v"].at[bi, slot].set(v[:, 0])
    cp = cache["pos"].at[bi, slot].set(pos.astype(jnp.int32))

    age = pos[:, None] - cp                       # [B, S_cache]
    valid = (cp >= 0) & (age >= 0)
    if kind == "local" and cfg.window:
        valid &= age < cfg.window
    ctx = sdpa(
        q, ck, cv, valid[:, None, :], cfg.head_dim**-0.5,
        cfg.attn_logit_softcap,
    )
    y = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])
    return y, {"k": ck, "v": cv, "pos": cp}


# --------------------------------------------------------------------------
# cross attention (whisper decoder)
# --------------------------------------------------------------------------

def cross_attn_init(key, cfg: ModelConfig) -> Params:
    dt = cdtype(cfg)
    ks = jax.random.split(key, 4)
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "wq": dense_init(ks[0], (d, h, hd), d, dt),
        "wk": dense_init(ks[1], (d, h, hd), d, dt),
        "wv": dense_init(ks[2], (d, h, hd), d, dt),
        "wo": dense_init(ks[3], (h, hd, d), h * hd, dt),
    }


def cross_attn_kv(cfg: ModelConfig, p: Params, enc: jax.Array):
    k = jnp.einsum("bsd,dhk->bshk", enc, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc, p["wv"])
    return k, v


def cross_attn_apply(
    cfg: ModelConfig, p: Params, x: jax.Array, kv: tuple[jax.Array, jax.Array]
) -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k, v = kv
    ctx = full_attention(
        q, k, v, causal=False, window=0, scale=cfg.head_dim**-0.5
    )
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])


# --------------------------------------------------------------------------
# MLA — multi-head latent attention (deepseek-v2, minicpm3)
# --------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig) -> Params:
    dt = cdtype(cfg)
    ks = jax.random.split(key, 6)
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    p: Params = {}
    if cfg.q_lora_rank > 0:
        p["q_a"] = dense_init(ks[0], (d, cfg.q_lora_rank), d, dt)
        p["q_a_norm"] = rmsnorm_init(cfg.q_lora_rank, dt)
        p["q_b"] = dense_init(
            ks[1], (cfg.q_lora_rank, h, dn + dr), cfg.q_lora_rank, dt
        )
    else:
        p["q_proj"] = dense_init(ks[1], (d, h, dn + dr), d, dt)
    p["kv_a"] = dense_init(ks[2], (d, cfg.kv_lora_rank + dr), d, dt)
    p["kv_a_norm"] = rmsnorm_init(cfg.kv_lora_rank, dt)
    p["kv_b"] = dense_init(
        ks[3], (cfg.kv_lora_rank, h, dn + dv), cfg.kv_lora_rank, dt
    )
    p["wo"] = dense_init(ks[4], (h, dv, d), h * dv, dt)
    return p


def _mla_q(cfg: ModelConfig, p: Params, x, positions):
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank > 0:
        qa = rmsnorm(p["q_a_norm"], jnp.einsum("bsd,dr->bsr", x, p["q_a"]),
                     cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", qa, p["q_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["q_proj"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(cfg: ModelConfig, p: Params, x, positions):
    dr = cfg.qk_rope_head_dim
    ckv = jnp.einsum("bsd,dr->bsr", x, p["kv_a"])
    c, k_rope = ckv[..., : cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank :]
    c = rmsnorm(p["kv_a_norm"], c, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return c, k_rope[:, :, 0, :]  # [B,S,L], [B,S,dr]


def mla_apply(
    cfg: ModelConfig, p: Params, x: jax.Array, positions: jax.Array
) -> jax.Array:
    """Full-sequence MLA (naive expansion — train/prefill compute)."""
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    c, k_rope = _mla_ckv(cfg, p, x, positions)
    kv = jnp.einsum("bsl,lhk->bshk", c, p["kv_b"])
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], k_nope.shape[:3] + (dr,))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    ctx = full_attention(
        q, k, v, causal=True, window=0, scale=(dn + dr) ** -0.5
    )
    return jnp.einsum("bshv,hvd->bsd", ctx, p["wo"])


def mla_cache_init(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    dt = cdtype(cfg)
    return {
        "c": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), dt),
        "pos": jnp.full((batch, max_seq), -1, jnp.int32),
    }


def mla_prefill(
    cfg: ModelConfig, p: Params, x, positions, cache
) -> tuple[jax.Array, dict]:
    y = mla_apply(cfg, p, x, positions)
    c, k_rope = _mla_ckv(cfg, p, x, positions)
    cache = {
        "c": jax.lax.dynamic_update_slice(cache["c"], c, (0, 0, 0)),
        "k_rope": jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope, (0, 0, 0)
        ),
        "pos": jax.lax.dynamic_update_slice(
            cache["pos"], positions.astype(jnp.int32), (0, 0)
        ),
    }
    return y, cache


def mla_decode(
    cfg: ModelConfig, p: Params, x, pos, cache
) -> tuple[jax.Array, dict]:
    """Absorbed-matrix MLA decode: attention runs entirely in the compressed
    latent space — the cache holds only (kv_lora + rope) per token."""
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    b = x.shape[0]
    q_nope, q_rope = _mla_q(cfg, p, x, pos[:, None])    # [B,1,H,dn],[B,1,H,dr]
    c_new, kr_new = _mla_ckv(cfg, p, x, pos[:, None])   # [B,1,L],[B,1,dr]

    bi = jnp.arange(b)
    cc = cache["c"].at[bi, pos].set(c_new[:, 0])
    ckr = cache["k_rope"].at[bi, pos].set(kr_new[:, 0])
    cp = cache["pos"].at[bi, pos].set(pos.astype(jnp.int32))

    w_uk = p["kv_b"][..., :dn]   # [L, H, dn]
    w_uv = p["kv_b"][..., dn:]   # [L, H, dv]
    q_abs = jnp.einsum("bqhd,lhd->bqhl", q_nope, w_uk)
    scores = (
        jnp.einsum("bqhl,bsl->bhqs", q_abs.astype(jnp.float32),
                   cc.astype(jnp.float32))
        + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                     ckr.astype(jnp.float32))
    ) * (dn + dr) ** -0.5
    valid = (cp >= 0) & (cp <= pos[:, None])
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    prob = jax.nn.softmax(scores, axis=-1).astype(cc.dtype)
    ctx_c = jnp.einsum("bhqs,bsl->bqhl", prob, cc)
    ctx = jnp.einsum("bqhl,lhv->bqhv", ctx_c, w_uv)
    y = jnp.einsum("bshv,hvd->bsd", ctx, p["wo"])
    return y, {"c": cc, "k_rope": ckr, "pos": cp}
