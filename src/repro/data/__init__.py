from repro.data.synthetic import synthetic_batches
from repro.data.sim_dataset import sim_token_batches

__all__ = ["synthetic_batches", "sim_token_batches"]
