"""Sharded Phase-III dataset: streaming writer + reader.

The paper's Phase III aggregates thousands of per-run outputs into one big
ML dataset (§2.10). :class:`DatasetWriter` is that aggregation step wired
into the sweep loop: at every chunk boundary it drains the instances that
just *finished* — their :class:`~repro.core.record.TraceBuffer` rows,
terminal metrics and parameter draws — and packs them into size-bounded
shards on disk:

    root/
      shard_00000.npz       # columnar arrays (see _write_shard)
      records_00000.jsonl   # one aliased dict record per instance
      ...
      manifest.json         # roster, configs, aliases, shard index,
                            # fault events, summary

Resume safety: an instance is drained only once ``done``, *after* the fault
hook has had its chance to revert it, and the writer re-scans existing
shards on construction — so a killed-and-restarted sweep (checkpoint
resume) appends exactly the instances not yet persisted. Combined with the
recorder's absolute-row indexing, the pipeline never drops or duplicates a
row end to end.

:class:`ShardedDataset` is the consumer side: records, time series and the
token corpus that :mod:`repro.data.sim_dataset` feeds to LM training.
"""

from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import (
    _PARAM_COLUMNS,
    metrics_to_columns,
    records_from_columns,
)
from repro.ckpt.io import fsync_file, fsync_dir
from repro.core.record import valid_rows as _valid_rows
from repro.core.scenarios import get_scenario
from repro.core.sweep import SweepConfig, SweepState
from repro.core.tokens import trace_token_streams, vocab_size

MANIFEST = "manifest.json"
FORMAT = "webots-hpc-phase3/v1"


def _shard_paths(root: str, idx: int) -> tuple[str, str]:
    return (
        os.path.join(root, f"shard_{idx:05d}.npz"),
        os.path.join(root, f"records_{idx:05d}.jsonl"),
    )


class DatasetWriter:
    """Streams a recording sweep into npz/jsonl shards + a manifest.

    Call :meth:`drain` at chunk boundaries (``run_with_failures`` does this
    when handed a writer) and :meth:`finalize` once the sweep completes.
    ``shard_size`` bounds instances per shard; the last shard may be
    smaller. The pipelined sweep loop uses the split
    :meth:`begin_drain` / :meth:`finish_drain` form instead, so the
    device-side gather is enqueued ahead of the next chunk and the
    npz/jsonl compression overlaps that chunk's device compute — the
    written bytes are identical either way. Instances are drained in
    logical-id order regardless of which device block computed them, so
    shard layout is device-count- and pipeline-invariant (tested).
    """

    def __init__(
        self,
        root: str,
        cfg: SweepConfig,
        shard_size: int = 16,
        n_buckets: int = 16,
        v_max: float = 40.0,
    ) -> None:
        if cfg.record is None:
            raise ValueError(
                "DatasetWriter needs a recording sweep: set "
                "SweepConfig.record (repro.core.record.RecordConfig)"
            )
        if shard_size < 1:
            raise ValueError(f"shard_size must be >= 1, got {shard_size}")
        self.root = root
        self.cfg = cfg
        self.shard_size = shard_size
        self.n_buckets = n_buckets
        self.v_max = v_max
        os.makedirs(root, exist_ok=True)
        # resume: instances already persisted by a previous (killed) run.
        # The npz is the shard's commit point (_write_shard replaces it
        # LAST), so scanning shard_*.npz sees only complete shards; stale
        # temp files from a mid-write kill start with "." and can't match.
        # A committed-looking shard that is truncated or corrupt (torn
        # non-atomic filesystem, bit rot) is DETECTED here — its files are
        # removed and its instances forgotten, so the resumed sweep drains
        # them again instead of shipping a broken dataset.
        self._shards: list[dict[str, Any]] = []
        self._written: set[int] = set()
        self.repaired: list[int] = []  # shard indices dropped as corrupt
        for path in sorted(glob.glob(os.path.join(root, "shard_*.npz"))):
            stem = os.path.basename(path)[len("shard_"):-len(".npz")]
            if not stem.isdigit():
                continue  # not a committed shard of this layout
            ids = self._read_shard_ids(path)
            if ids is None:
                self._discard_shard_files(int(stem))
                self.repaired.append(int(stem))
                continue
            self._shards.append(self._shard_entry(int(stem), ids))
            self._written.update(ids)
        self._next_shard = (
            max(
                [s["index"] for s in self._shards] + self.repaired,
                default=-1,
            )
            + 1
        )
        self._pending: dict[int, dict[str, Any]] = {}
        # ids gathered by a begin_drain whose finish_drain hasn't landed
        # yet: reserved so overlapping handles can never drain an
        # instance twice (the no-duplicate-rows guarantee holds for any
        # look-ahead depth, not just the run loop's 1-chunk pipeline)
        self._inflight: set[int] = set()

    def _read_shard_ids(self, path: str) -> list[int] | None:
        """Instance ids of a shard npz, or None when the file is truncated
        or otherwise unreadable (the corrupt-shard detection primitive)."""
        try:
            with np.load(path, allow_pickle=False) as z:
                if "instance" not in z.files:
                    return None
                return [int(i) for i in z["instance"]]
        except Exception:
            return None

    def _discard_shard_files(self, idx: int) -> None:
        for p in _shard_paths(self.root, idx):
            try:
                os.remove(p)
            except OSError:
                pass

    def verify_shards(self) -> list[int]:
        """Audit every committed shard against disk; drop the broken ones.

        A shard whose npz no longer loads (truncated by a torn write, a
        failing disk, or the chaos fault model) is deleted together with
        its records jsonl, and its instances are removed from the
        ``written`` set — the next :meth:`drain` re-persists them from
        sweep state, which still holds every instance's trace. Returns the
        repaired shard indices. The unattended-run supervisor calls this
        after suspected-corruption events and before :meth:`finalize`, so
        a manifest can never reference a shard that does not round-trip.
        """
        bad: list[int] = []
        for entry in list(self._shards):
            npz_path, _ = _shard_paths(self.root, entry["index"])
            if self._read_shard_ids(npz_path) != entry["instances"]:
                bad.append(entry["index"])
                self._shards.remove(entry)
                self._written.difference_update(entry["instances"])
                self._discard_shard_files(entry["index"])
        self.repaired.extend(bad)
        return bad

    @staticmethod
    def _shard_entry(idx: int, ids: list[int]) -> dict[str, Any]:
        npz, jsonl = _shard_paths("", idx)
        return {
            "index": idx,
            "file": os.path.basename(npz),
            "records": os.path.basename(jsonl),
            "n_instances": len(ids),
            "instances": [int(i) for i in ids],
        }

    @property
    def written(self) -> set[int]:
        return set(self._written)

    # ---------------- streaming drain ----------------

    def begin_drain(self, state: SweepState, done: np.ndarray | None = None):
        """Enqueue the device-side gather for every newly-finished instance.

        Returns an opaque handle for :meth:`finish_drain`, or ``None`` when
        nothing new finished. The gather is dispatched asynchronously and
        ONLY covers the newly-done rows (the trace slab is the bulk of the
        state and most of it belongs to instances that are still running or
        already persisted). Nothing is pulled to host or written yet — the
        pipelined sweep loop calls this *before* dispatching the next
        chunk, so the gather lands on the device stream ahead of the next
        chunk's work, and the host-side :meth:`finish_drain` then overlaps
        that chunk's compute.

        ``done`` lets a caller that already synced the completion bitmap
        pass it in; otherwise it is read from ``state``.
        """
        if done is None:
            done = np.asarray(jax.device_get(state.done))
        new = [
            int(i) for i in np.flatnonzero(done)
            if int(i) not in self._written
            and int(i) not in self._pending
            and int(i) not in self._inflight
        ]
        if not new:
            return None
        self._inflight.update(new)
        idx = jnp.asarray(new)
        sub = jax.tree.map(
            lambda x: x[idx],
            (state.metrics, state.params, state.horizon,
             state.scenario_id, state.trace),
        )
        return (new, sub)

    def finish_drain(self, handle) -> int:
        """Pull a :meth:`begin_drain` gather to host, buffer it, flush full
        shards. Returns how many instances were newly drained."""
        if handle is None:
            return 0
        new, sub = handle
        self._inflight.difference_update(new)
        metrics, params, horizon, sids, trace = jax.tree.map(
            np.asarray, jax.device_get(sub)
        )
        for j, i in enumerate(new):
            self._pending[i] = {
                "metrics": jax.tree.map(lambda x: x[j], metrics),
                "params": jax.tree.map(lambda x: x[j], params),
                "horizon": horizon[j],
                "scenario_id": sids[j],
                "trace": jax.tree.map(lambda x: x[j], trace),
            }
        while len(self._pending) >= self.shard_size:
            self._flush_one_shard()
        return len(new)

    def drain(self, state: SweepState) -> int:
        """Synchronous drain: gather + persist every newly-finished
        instance in one call (``begin_drain`` + ``finish_drain``).

        Call after fault handling: a ``done`` bit is only trusted once the
        chunk's failure injection can no longer revert it. Returns how
        many instances were newly drained.
        """
        return self.finish_drain(self.begin_drain(state))

    def _flush_one_shard(self) -> None:
        ids = sorted(self._pending)[: self.shard_size]
        rows = [self._pending.pop(i) for i in ids]
        self._write_shard(ids, rows)

    def _write_shard(self, ids: list[int], rows: list[dict]) -> None:
        idx = self._next_shard
        self._next_shard += 1
        cfg, rec = self.cfg, self.cfg.record
        stack = lambda key: jax.tree.map(  # noqa: E731
            lambda *xs: np.stack(xs), *[r[key] for r in rows]
        )
        metrics, params, trace = stack("metrics"), stack("params"), stack("trace")
        horizon = np.asarray([r["horizon"] for r in rows])
        sids = np.asarray([r["scenario_id"] for r in rows])
        valid = np.asarray(_valid_rows(horizon, rec.record_every))

        cols = metrics_to_columns(
            metrics, params, scenario_ids=sids, scenario_names=cfg.scenarios
        )
        records = records_from_columns(cols)
        arrays: dict[str, np.ndarray] = {
            "instance": np.asarray(ids, np.int64),
            "scenario_id": sids.astype(np.int64),
            "horizon": horizon.astype(np.int64),
            "valid_rows": valid.astype(np.int64),
            "series": trace.series,
        }
        for k, v in cols.items():
            if k in ("instance", "scenario_id", "scenario"):
                continue  # stored above / derivable from the roster
            prefix = "p" if k in _PARAM_COLUMNS else "m"
            arrays[f"{prefix}_{k}"] = v
        if rec.k_slots:
            arrays.update(lane=trace.lane, speed=trace.speed,
                          active=trace.active)
            tokens, lengths = trace_token_streams(
                trace.lane, trace.speed, trace.active, valid, cfg.sim,
                n_buckets=self.n_buckets, v_max=self.v_max,
            )
            arrays.update(tokens=tokens, stream_len=lengths.astype(np.int64))

        npz_path, jsonl_path = _shard_paths(self.root, idx)
        # commit order matters for kill/resume: the records jsonl lands
        # first, the npz replace is the single commit point the resume scan
        # keys on — a kill in between leaves an orphan jsonl the re-written
        # shard overwrites, never a committed npz missing its records.
        # Temp names start with "." so the scan glob can never match them.
        tmp = os.path.join(self.root, f".tmp_records_{idx:05d}.jsonl")
        with open(tmp, "w") as f:
            for logical_id, record in zip(ids, records):
                record["instance"] = int(logical_id)  # logical, not row
                f.write(json.dumps(record) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, jsonl_path)

        tmp = os.path.join(self.root, f".tmp_shard_{idx:05d}.npz")
        np.savez_compressed(tmp, **arrays)
        fsync_file(tmp)
        os.replace(tmp, npz_path)
        fsync_dir(self.root)

        self._shards.append(self._shard_entry(idx, ids))
        self._written.update(ids)

    # ---------------- finalize ----------------

    def finalize(
        self,
        summary: dict | None = None,
        fault_info: dict | None = None,
    ) -> str:
        """Flush the partial tail shard and write the manifest."""
        while self._pending:
            self._flush_one_shard()
        cfg, rec = self.cfg, self.cfg.record
        manifest = {
            "format": FORMAT,
            "sweep": dataclasses.asdict(cfg),
            "scenarios": list(cfg.scenarios),
            "record": dataclasses.asdict(rec),
            "n_buckets": self.n_buckets,
            "v_max": self.v_max,
            "vocab_size": vocab_size(cfg.sim, self.n_buckets),
            "metric_aliases": {
                name: dict(get_scenario(name).metric_aliases)
                for name in dict.fromkeys(cfg.scenarios)
            },
            "n_instances_written": len(self._written),
            "shards": sorted(self._shards, key=lambda s: s["index"]),
            "summary": summary,
            "fault_events": (fault_info or {}).get("failure_events", []),
            "fault_info": fault_info,
            "repaired_shards": sorted(self.repaired),
        }
        path = os.path.join(self.root, MANIFEST)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        fsync_dir(self.root)
        return path


def write_dataset(
    root: str,
    state: SweepState,
    cfg: SweepConfig,
    shard_size: int = 16,
    summary: dict | None = None,
    fault_info: dict | None = None,
    **writer_kw,
) -> str:
    """One-shot: shard out a finished recording sweep's state."""
    w = DatasetWriter(root, cfg, shard_size=shard_size, **writer_kw)
    w.drain(state)
    return w.finalize(summary=summary, fault_info=fault_info)


class ShardedDataset:
    """Reader for a :class:`DatasetWriter` directory."""

    def __init__(self, root: str, manifest: dict) -> None:
        self.root = root
        self.manifest = manifest

    @classmethod
    def load(cls, root: str) -> "ShardedDataset":
        with open(os.path.join(root, MANIFEST)) as f:
            return cls(root, json.load(f))

    @property
    def n_instances(self) -> int:
        return int(self.manifest["n_instances_written"])

    @property
    def fields(self) -> list[str]:
        return list(self.manifest["record"]["fields"])

    def _shard_files(self) -> list[str]:
        return [
            os.path.join(self.root, s["file"])
            for s in self.manifest["shards"]
        ]

    def iter_shards(self) -> Iterator[dict[str, np.ndarray]]:
        for path in self._shard_files():
            with np.load(path, allow_pickle=False) as z:
                yield dict(z)

    def _concat(self, *keys: str) -> list[np.ndarray]:
        """One decompression pass per shard, however many keys are read."""
        parts: dict[str, list[np.ndarray]] = {k: [] for k in keys}
        for path in self._shard_files():
            with np.load(path, allow_pickle=False) as z:
                for k in keys:
                    parts[k].append(z[k])
        if not parts[keys[0]]:
            raise ValueError(f"dataset at {self.root} has no shards")
        return [np.concatenate(parts[k], axis=0) for k in keys]

    def records(self) -> list[dict[str, Any]]:
        out: list[dict[str, Any]] = []
        for s in self.manifest["shards"]:
            with open(os.path.join(self.root, s["records"])) as f:
                out.extend(json.loads(line) for line in f if line.strip())
        return out

    def series(self) -> tuple[list[str], np.ndarray, np.ndarray]:
        """(field names, [n, R, F] series, [n] valid-row counts)."""
        series, valid = self._concat("series", "valid_rows")
        return self.fields, series, valid

    def token_streams(self) -> tuple[np.ndarray, np.ndarray]:
        """([n, L] padded streams, [n] true lengths)."""
        streams, lengths = self._concat("tokens", "stream_len")
        return streams, lengths

    def token_corpus(self) -> np.ndarray:
        """1-D concatenation of every stream with PAD tails stripped —
        what the LM batcher (:func:`repro.data.sim_dataset.sim_token_batches`)
        packs into fixed-shape training windows."""
        streams, lengths = self.token_streams()
        return np.concatenate(
            [s[:n] for s, n in zip(streams, lengths)]
        )
