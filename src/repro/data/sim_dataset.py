"""Phase-III data: LM training batches drawn from simulation sweeps.

This is the paper's whole point — the aggregated output dataset of thousands
of randomized simulation runs becomes ML training data. Token streams come
from the *production sweep path*: either a sharded dataset directory written
by :class:`repro.data.shards.DatasetWriter` (``shard_dir=...`` — sweep once,
train many times) or an in-process recording sweep through the same
``SweepRunner`` engine the launcher uses (dispatch planning, compaction and
all). Either way the batcher packs one flat token corpus into fixed-shape
next-token-prediction windows.
"""

from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.core.record import RecordConfig
from repro.core.scenario import SimConfig
from repro.core.sweep import SweepConfig, SweepRunner
from repro.core.tokens import trace_token_streams, vocab_size, PAD


def sim_token_corpus(
    sim: SimConfig,
    n_instances: int,
    seed: int = 0,
    n_steps: int = 400,
    record_every: int = 10,
    k_slots: int = 8,
    scenario_mix: tuple[str, ...] = (),
    dispatch: str = "auto",
) -> np.ndarray:
    """Run a recording sweep in-process and concatenate every instance's
    token stream (PAD tails stripped).

    This is the real sweep engine — ``SweepRunner`` with a
    :class:`~repro.core.record.RecordConfig` — not a side-channel rollout,
    so the training corpus is bit-identical to what a launched sweep's
    shards contain for the same config.
    """
    cfg = SweepConfig(
        n_instances=n_instances,
        steps_per_instance=n_steps,
        chunk_steps=n_steps,
        sim=sim,
        seed=seed,
        scenario_mix=scenario_mix,
        dispatch=dispatch,
        # token channels only: scalar series would be dead weight here
        record=RecordConfig(record_every=record_every, fields=(),
                            k_slots=k_slots),
    )
    state = SweepRunner(cfg).run()
    trace = jax.tree.map(
        lambda x: np.asarray(jax.device_get(x)), state.trace
    )
    horizon = np.asarray(jax.device_get(state.horizon))
    streams, lengths = trace_token_streams(
        trace.lane, trace.speed, trace.active, horizon // record_every, sim
    )
    return np.concatenate([s[:n] for s, n in zip(streams, lengths)])


def shard_token_corpus(shard_dir: str) -> tuple[np.ndarray, int]:
    """(flat token corpus, token vocab size) from a written dataset dir.

    The vocab comes from the manifest — the shards may have been written
    with a different SimConfig / bucket count than the caller's, and the
    corpus's true vocabulary is what the model must cover.
    """
    from repro.data.shards import ShardedDataset  # deferred: optional path

    ds = ShardedDataset.load(shard_dir)
    return ds.token_corpus(), int(ds.manifest["vocab_size"])


def sim_token_batches(
    cfg: ModelConfig,
    sim: SimConfig,
    batch: int,
    seq: int,
    n_instances: int = 8,
    seed: int = 0,
    start_step: int = 0,
    shard_dir: str | None = None,
) -> Iterator[dict]:
    """Fixed-shape batches over the sim corpus (wrap-around epochs).

    ``shard_dir`` points at a :class:`~repro.data.shards.DatasetWriter`
    output directory (sweep → shards → train); without it a small recording
    sweep runs in-process. The model's vocab must be ≥ the sim token
    vocabulary (``repro.core.tokens.vocab_size``).
    """
    if shard_dir is not None:
        corpus, need_vocab = shard_token_corpus(shard_dir)
    else:
        corpus = sim_token_corpus(sim, n_instances, seed)
        need_vocab = vocab_size(sim)
    assert cfg.vocab_size >= need_vocab, (
        f"model vocab {cfg.vocab_size} < sim token vocab {need_vocab}"
    )
    span = batch * (seq + 1)
    n = corpus.shape[0]
    step = start_step
    while True:
        off = (step * span) % max(n - span, 1)
        window = corpus[off : off + span]
        if window.shape[0] < span:
            window = np.pad(window, (0, span - window.shape[0]),
                            constant_values=PAD)
        arr = jnp.asarray(window.reshape(batch, seq + 1).astype(np.int32))
        out = {"tokens": arr[:, :-1], "labels": arr[:, 1:]}
        if cfg.is_encdec:
            # audio-stub frames: the sim stream conditions the decoder only
            out["frames"] = jax.random.normal(
                jax.random.fold_in(jax.random.key(seed), step),
                (batch, cfg.enc_ctx, cfg.d_model), jnp.dtype(cfg.dtype),
            )
        if cfg.mrope_sections:
            pos = jnp.broadcast_to(jnp.arange(seq)[None], (batch, seq))
            out["mrope_pos"] = jnp.broadcast_to(
                pos[None], (3, batch, seq)
            ).astype(jnp.int32)
        yield out
        step += 1
