"""Phase-III data: LM training batches drawn from simulation sweeps.

This is the paper's whole point — the aggregated output dataset of thousands
of randomized simulation runs becomes ML training data. Token streams come
from ``repro.core.tokens``; this module packs them into fixed-shape
next-token-prediction batches.
"""

from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.core.scenario import SimConfig, sample_scenario_params
from repro.core.tokens import sweep_token_dataset, vocab_size, PAD


def sim_token_corpus(
    sim: SimConfig,
    n_instances: int,
    seed: int = 0,
    n_steps: int = 400,
    record_every: int = 10,
    k_slots: int = 8,
) -> np.ndarray:
    """Run a small sweep and concatenate every instance's token stream."""
    keys = jax.vmap(
        lambda i: jax.random.fold_in(jax.random.key(seed), i)
    )(jnp.arange(n_instances))
    params = jax.vmap(lambda k: sample_scenario_params(k, sim))(keys)
    streams = sweep_token_dataset(
        keys, params, sim, n_steps=n_steps, record_every=record_every,
        k_slots=k_slots,
    )
    return np.asarray(jax.device_get(streams)).reshape(-1)


def sim_token_batches(
    cfg: ModelConfig,
    sim: SimConfig,
    batch: int,
    seq: int,
    n_instances: int = 8,
    seed: int = 0,
    start_step: int = 0,
) -> Iterator[dict]:
    """Fixed-shape batches over the sim corpus (wrap-around epochs).

    The model's vocab must be ≥ the sim token vocabulary
    (``repro.core.tokens.vocab_size``).
    """
    corpus = sim_token_corpus(sim, n_instances, seed)
    assert cfg.vocab_size >= vocab_size(sim), (
        f"model vocab {cfg.vocab_size} < sim vocab {vocab_size(sim)}"
    )
    span = batch * (seq + 1)
    n = corpus.shape[0]
    step = start_step
    while True:
        off = (step * span) % max(n - span, 1)
        window = corpus[off : off + span]
        if window.shape[0] < span:
            window = np.pad(window, (0, span - window.shape[0]),
                            constant_values=PAD)
        arr = jnp.asarray(window.reshape(batch, seq + 1).astype(np.int32))
        out = {"tokens": arr[:, :-1], "labels": arr[:, 1:]}
        if cfg.is_encdec:
            # audio-stub frames: the sim stream conditions the decoder only
            out["frames"] = jax.random.normal(
                jax.random.fold_in(jax.random.key(seed), step),
                (batch, cfg.enc_ctx, cfg.d_model), jnp.dtype(cfg.dtype),
            )
        if cfg.mrope_sections:
            pos = jnp.broadcast_to(jnp.arange(seq)[None], (batch, seq))
            out["mrope_pos"] = jnp.broadcast_to(
                pos[None], (3, batch, seq)
            ).astype(jnp.int32)
        yield out
        step += 1
