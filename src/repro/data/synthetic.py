"""Synthetic token pipeline: deterministic, seekable (restart-friendly)."""

from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig


def synthetic_batches(
    cfg: ModelConfig,
    batch: int,
    seq: int,
    seed: int = 0,
    start_step: int = 0,
    sharding=None,
    pattern: str = "walk",
) -> Iterator[dict]:
    """Yields {"tokens", "labels"} (+family extras) forever; step-indexed keys
    make the stream seekable for bit-exact restart.

    ``pattern="walk"`` emits learnable sequences (random start, +1 successor
    walk over the vocab) so loss curves are meaningful; ``"uniform"`` emits
    i.i.d. tokens (pure-throughput benchmarking).
    """
    base = jax.random.key(seed)
    step = start_step

    @jax.jit
    def gen(k):
        if pattern == "uniform":
            toks = jax.random.randint(k, (batch, seq + 1), 0, cfg.vocab_size)
        else:
            start = jax.random.randint(k, (batch, 1), 0, cfg.vocab_size)
            toks = (start + jnp.arange(seq + 1)[None]) % cfg.vocab_size
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.is_encdec:
            out["frames"] = jax.random.normal(
                jax.random.fold_in(k, 1), (batch, cfg.enc_ctx, cfg.d_model),
                jnp.dtype(cfg.dtype),
            )
        if cfg.mrope_sections:
            pos = jnp.broadcast_to(jnp.arange(seq)[None], (batch, seq))
            out["mrope_pos"] = jnp.broadcast_to(
                pos[None], (3, batch, seq)
            ).astype(jnp.int32)
        return out

    while True:
        out = gen(jax.random.fold_in(base, step))
        if sharding is not None:
            out = jax.tree.map(jax.device_put, out, sharding)
        yield out
        step += 1
