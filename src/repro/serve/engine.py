"""Serving engine: jitted prefill/decode + slot-based continuous batching.

``ServeEngine`` keeps a fixed pool of decode slots (static shapes — one
compiled decode step serves any request mix). Requests join free slots via a
per-slot prefill; finished slots are recycled immediately (continuous
batching). Sampling is greedy or temperature-based, per request.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig, ServeConfig
from repro.models.registry import Model


def greedy_generate(
    model: Model,
    params: Any,
    prompt: jax.Array,       # [B, S0]
    steps: int,
    max_seq: int | None = None,
    extras: dict | None = None,
) -> jax.Array:
    """Simple batched greedy generation (one prefill + scanned decode)."""
    b, s0 = prompt.shape
    max_seq = max_seq or (s0 + steps)
    cache = model.init_cache(b, max_seq)
    batch = {"tokens": prompt, **(extras or {})}
    logits, cache = jax.jit(model.prefill)(params, cache, batch)
    tok = jnp.argmax(logits, axis=-1)

    def body(carry, i):
        tok, cache = carry
        pos = jnp.full((b,), s0, jnp.int32) + i
        logits, cache = model.decode(params, cache, tok, pos)
        nxt = jnp.argmax(logits, axis=-1)
        return (nxt, cache), tok

    (_, _), toks = jax.lax.scan(
        body, (tok, cache), jnp.arange(steps, dtype=jnp.int32)
    )
    return toks.T  # [B, steps]


@dataclasses.dataclass
class _Slot:
    request_id: int | None = None
    pos: int = 0
    out: list[int] = dataclasses.field(default_factory=list)
    remaining: int = 0


class ServeEngine:
    """Continuous batching over a fixed slot pool.

    Usage::

        eng = ServeEngine(model, params, ServeConfig(max_batch=4, max_seq=256))
        eng.submit(tokens, max_new=32)   # any number of requests
        results = eng.run()              # {request_id: [token, ...]}
    """

    def __init__(self, model: Model, params: Any, sc: ServeConfig) -> None:
        self.model = model
        self.params = params
        self.sc = sc
        self.cache = model.init_cache(sc.max_batch, sc.max_seq)
        self.slots = [_Slot() for _ in range(sc.max_batch)]
        self.queue: list[tuple[int, np.ndarray, int]] = []
        self.results: dict[int, list[int]] = {}
        self._next_id = 0
        self._tok = jnp.zeros((sc.max_batch,), jnp.int32)

        cfg = model.cfg

        def decode_step(params, cache, tok, pos, live):
            logits, cache = model.decode(params, cache, tok, pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            # dead slots keep emitting 0 and don't advance their cache pos —
            # their writes land at pos 0 repeatedly and are masked on read
            return jnp.where(live, nxt, 0), cache

        self._decode = jax.jit(decode_step)

        def prefill_one(params, cache, tokens, slot_tok_buffer):
            """Prefill a single sequence into slot 0 of a 1-row cache."""
            batch = {"tokens": tokens}
            logits, cache = model.prefill(params, cache, batch)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        self._prefill_one = jax.jit(prefill_one)

    # ------------------------------------------------------------------
    def submit(self, tokens: np.ndarray, max_new: int) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append((rid, np.asarray(tokens), max_new))
        return rid

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.request_id is not None or not self.queue:
                continue
            rid, tokens, max_new = self.queue.pop(0)
            # per-slot prefill on a 1-row cache view, then splice into pool
            one_cache = self.model.init_cache(1, self.sc.max_seq)
            tok, one_cache = self._prefill_one(
                self.params, one_cache, jnp.asarray(tokens[None]), None
            )
            self.cache = jax.tree.map(
                lambda pool, one: _splice_row(pool, one, i),
                self.cache, one_cache,
            )
            self._tok = self._tok.at[i].set(tok[0])
            self.slots[i] = _Slot(
                request_id=rid, pos=tokens.shape[0],
                out=[int(tok[0])], remaining=max_new - 1,
            )

    def run(self) -> dict[int, list[int]]:
        while self.queue or any(s.request_id is not None for s in self.slots):
            self._admit()
            live = jnp.asarray(
                [s.request_id is not None for s in self.slots]
            )
            pos = jnp.asarray(
                [s.pos if s.request_id is not None else 0 for s in self.slots],
                jnp.int32,
            )
            nxt, self.cache = self._decode(
                self.params, self.cache, self._tok, pos, live
            )
            self._tok = nxt
            host = np.asarray(jax.device_get(nxt))
            for i, slot in enumerate(self.slots):
                if slot.request_id is None:
                    continue
                slot.out.append(int(host[i]))
                slot.pos += 1
                slot.remaining -= 1
                if slot.remaining <= 0 or slot.pos >= self.sc.max_seq - 1:
                    self.results[slot.request_id] = slot.out
                    self.slots[i] = _Slot()
        return self.results


def _splice_row(pool: jax.Array, one: jax.Array, i: int) -> jax.Array:
    """Copy row 0 of ``one`` into row-``i`` of the batch axis of ``pool``.

    Cache leaves are either [B, ...] or [L, B, ...] (stacked layers) — the
    batch axis is wherever ``one`` has size 1 with pool size ≥ 1 at the same
    rank position (axis 0 or 1).
    """
    if pool.ndim == 0:
        return pool
    if one.shape[0] == 1 and pool.shape[0] != 1:
        return pool.at[i].set(one[0])
    return pool.at[:, i].set(one[:, 0])
