"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

THE FIRST TWO LINES set the 512-placeholder-device flag — before ANY other
import, since jax locks the device count on first init. Do not import this
module from test/bench code (it would flip their device world); it is a
__main__ entry point.

Per cell: build the production mesh, lower the cell's step function with
explicit in/out shardings, ``.compile()``, then record
``memory_analysis()`` / ``cost_analysis()`` / collective wire bytes and the
derived roofline terms into ``experiments/dryrun/*.json`` (EXPERIMENTS.md
§Dry-run and §Roofline read from these artifacts).

Usage::

    python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
    python -m repro.launch.dryrun --all                  # every cell
    python -m repro.launch.dryrun --all --multi-pod      # 2x16x16 mesh
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.config import SHAPES, TrainConfig, get_arch       # noqa: E402
from repro.configs import ALL_ARCHS                          # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.launch.specs import (                             # noqa: E402
    cell_is_applicable,
    input_specs,
)
from repro.launch.costing import extrapolated_costs         # noqa: E402
from repro.launch.roofline import (                          # noqa: E402
    roofline_report,
    model_flops,
)
from repro.models import build_model                         # noqa: E402
from repro.sharding import (                                 # noqa: E402
    batch_shardings,
    cache_shardings,
    opt_state_shardings,
    param_shardings,
)
from repro.train.step import abstract_train_state, make_train_step  # noqa: E402
from repro.utils.hlo import collective_bytes                 # noqa: E402

ARTIFACT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"
)


# per-arch training knobs used for the big cells (memory-driven; fitting
# iteration documented in EXPERIMENTS.md §Dry-run). max microbatches is
# bounded by global_batch/dp_size = 256/16 = 16 (one row per data shard).
TRAIN_OVERRIDES: dict[str, dict] = {
    "deepseek-v2-236b": {"microbatches": 16, "remat": "full"},
    "gemma2-9b": {"microbatches": 4, "remat": "full"},
    "minicpm3-4b": {"microbatches": 16, "remat": "full"},
    "whisper-large-v3": {"microbatches": 16, "remat": "full"},
    "qwen2-vl-2b": {"microbatches": 4, "remat": "full"},
    "gemma2-2b": {"microbatches": 4, "remat": "full"},
    "recurrentgemma-2b": {"microbatches": 4, "remat": "full"},
    "rwkv6-3b": {"microbatches": 4, "remat": "full"},
    "olmoe-1b-7b": {"microbatches": 2, "remat": "full"},
    "qwen1.5-0.5b": {"microbatches": 2, "remat": "full"},
}


def build_cell(arch: str, shape_name: str, mesh, tc_overrides=None):
    """Returns (jitted_fn, example_args_abstract) for one cell."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        over = dict(TRAIN_OVERRIDES.get(arch, {}))
        over.update(tc_overrides or {})
        tc = TrainConfig(remat="full", **over) if "remat" not in over else \
            TrainConfig(**over)
        params, opt_state = abstract_train_state(model, tc)
        p_sh = param_shardings(cfg, params, mesh)
        o_sh = opt_state_shardings(cfg, opt_state, params, mesh)
        b_sh = batch_shardings(mesh, specs)
        step = make_train_step(model, tc)
        fn = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        )
        return fn, (params, opt_state, specs), tc

    params = model.abstract_params()
    p_sh = param_shardings(cfg, params, mesh)

    if shape.kind == "prefill":
        cache = model.abstract_cache(shape.global_batch, shape.seq_len)
        c_sh = cache_shardings(mesh, cache, shape.global_batch)
        b_sh = batch_shardings(mesh, specs)
        fn = jax.jit(
            model.prefill,
            in_shardings=(p_sh, c_sh, b_sh),
            out_shardings=(None, c_sh),
            donate_argnums=(1,),
        )
        return fn, (params, cache, specs), None

    # decode
    cache = specs["cache"]
    c_sh = cache_shardings(mesh, cache, shape.global_batch)
    tok_sh = batch_shardings(mesh, {"t": specs["tokens"]})["t"]
    pos_sh = batch_shardings(mesh, {"t": specs["pos"]})["t"]
    fn = jax.jit(
        model.decode,
        in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(1,),
    )
    return fn, (params, cache, specs["tokens"], specs["pos"]), None


def run_cell(
    arch: str, shape_name: str, multi_pod: bool, save: bool = True,
    tc_overrides=None, tag: str = "",
) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    ok, why = cell_is_applicable(cfg, shape)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "cell": cell_id, "status": "skip", "reason": why,
    }
    if not ok:
        print(f"[dryrun] SKIP {cell_id}: {why}")
        if save:
            _save(cell_id, result)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()
    fn, args, tc = build_cell(arch, shape_name, mesh, tc_overrides)
    from repro.sharding.ctx import activation_sharding

    with mesh, activation_sharding(mesh):
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # older JAX: one dict per program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    mem_info = {}
    if mem is not None:
        for field in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            if hasattr(mem, field):
                mem_info[field] = int(getattr(mem, field))

    # official (scanned) compile: memory + artifact. Cost totals come from the
    # trip-count-honest extrapolation coster (scan bodies are counted once by
    # HLO cost analysis — see launch/costing.py).
    raw_flops = float(cost.get("flops", 0.0)) if cost else 0.0
    raw_bytes = float(cost.get("bytes accessed", 0.0)) if cost else 0.0

    shape_cfg = SHAPES[shape_name]
    if multi_pod:
        # §Roofline is single-pod; multi-pod cells prove the 'pod' axis
        # shards (lower+compile+memory) without the costing pass
        ext = {
            "flops_per_device": raw_flops,
            "bytes_per_device": raw_bytes,
            "wire_bytes_per_device": coll.total_wire_bytes,
            "method": "scanned-hlo-raw(no-trip-count-correction)",
        }
    else:
        ext = extrapolated_costs(cfg, shape_cfg, mesh, tc)
    flops = ext["flops_per_device"]
    bytes_accessed = ext["bytes_per_device"]
    wire = ext["wire_bytes_per_device"]

    mf = model_flops(cfg, shape)
    report = roofline_report(
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        wire_bytes_per_device=wire,
        n_devices=n_dev,
        model_flops_global=mf,
    )

    result.update(
        status="ok",
        n_devices=int(n_dev),
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=mem_info,
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        wire_bytes_per_device=wire,
        cost_method=ext["method"],
        raw_scanned_flops_per_device=raw_flops,
        raw_scanned_bytes_per_device=raw_bytes,
        collectives_scanned_hlo={
            "counts": coll.counts,
            "wire_bytes": coll.wire_bytes,
            "total_wire_bytes": coll.total_wire_bytes,
        },
        model_flops_global=mf,
        roofline=report,
        train_overrides=(
            {"microbatches": tc.microbatches, "remat": tc.remat}
            if tc is not None else None
        ),
    )
    print(
        f"[dryrun] OK {cell_id}: compile={t_compile:.1f}s "
        f"flops/dev={flops:.3e} bytes/dev={bytes_accessed:.3e} "
        f"wire/dev={wire:.3e} "
        f"dominant={report['dominant']} "
        f"terms(c/m/n)={report['compute_s']:.2e}/{report['memory_s']:.2e}/"
        f"{report['collective_s']:.2e}s "
        f"roofline_frac={report['roofline_fraction']:.3f}"
    )
    if mem_info:
        print(f"[dryrun]    memory_analysis: {mem_info}")
    if save:
        _save(cell_id, result)
    return result


def _save(cell_id: str, result: dict) -> None:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, cell_id + ".json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat", default=None,
                    choices=["none", "full", "dots"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    overrides = {}
    if args.microbatches is not None:
        overrides["microbatches"] = args.microbatches
    if args.remat is not None:
        overrides["remat"] = args.remat

    archs = ALL_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, mp,
                             tc_overrides=overrides or None, tag=args.tag)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[dryrun] FAIL {arch}/{shape}/mp={mp}: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")


if __name__ == "__main__":
    main()
