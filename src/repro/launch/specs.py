"""ShapeDtypeStruct stand-ins for every (arch × shape) dry-run cell.

``input_specs(cfg, shape)`` returns the abstract inputs of the function the
cell lowers — ``train_step`` for training shapes, ``prefill`` for
inference-prefill, ``serve_step`` (one token against a seq_len cache) for
decode shapes. No device allocation anywhere (weak-type-correct, shardable).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig, ShapeConfig
from repro.models.registry import Model

I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "tokens": _sds((b, s), I32),
        "labels": _sds((b, s), I32),
    }
    if cfg.is_encdec:
        batch["frames"] = _sds((b, s, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.mrope_sections:
        batch["mrope_pos"] = _sds((3, b, s), I32)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((b, s), I32)}
    if cfg.is_encdec:
        # encoder consumes the seq_len frames; decoder starts from a prompt
        batch["frames"] = _sds((b, s, cfg.d_model), jnp.dtype(cfg.dtype))
        batch["tokens"] = _sds((b, min(s, 448)), I32)
    if cfg.mrope_sections:
        batch["mrope_pos"] = _sds((3, b, s), I32)
    return batch


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Inputs of serve_step: one new token against a seq_len-deep cache."""
    model = Model(cfg)
    b, s = shape.global_batch, shape.seq_len
    out = {
        "cache": model.abstract_cache(b, s),
        "tokens": _sds((b,), I32),
        "pos": _sds((b,), I32),
    }
    if cfg.mrope_sections:
        out["mrope_pos"] = _sds((3, b, 1), I32)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    if shape.kind == "train":
        return train_batch_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_batch_specs(cfg, shape)
    return decode_specs(cfg, shape)


def cell_is_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k runs only for sub-quadratic archs (assignment skip rule)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "full-attention KV at 512k context — skipped per assignment "
            "(run for SSM/hybrid/linear-attn only); see DESIGN.md §5"
        )
    return True, ""
