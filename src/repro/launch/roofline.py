"""Roofline math (TPU v5e constants) — EXPERIMENTS.md §Roofline.

Terms (all in seconds, per device; HLO numbers come from the partitioned
per-device module so no further division by chip count is needed):

    compute    = HLO_FLOPs_per_device / 197e12        (bf16 peak per chip)
    memory     = HLO_bytes_per_device / 819e9         (HBM bandwidth)
    collective = wire_bytes_per_device / 50e9         (per-link ICI)

``MODEL_FLOPS`` uses 6·N·D (train) / 2·N·D (inference) with N = active
params for MoE; the ratio MODEL_FLOPS / (HLO_FLOPs × chips) shows how much
compiled compute is "useful" (catches remat/redundancy waste — with full
remat the theoretical ceiling is 0.75 for training).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.config.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12      # bf16 FLOP/s per v5e chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link


def roofline_report(
    *,
    flops_per_device: float,
    bytes_per_device: float,
    wire_bytes_per_device: float,
    n_devices: int,
    model_flops_global: float,
) -> dict:
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = wire_bytes_per_device / ICI_BW
    terms = {
        "compute": compute_s, "memory": memory_s, "collective": collective_s
    }
    dominant = max(terms, key=terms.get)
    total_hlo_flops = flops_per_device * n_devices
    useful = (
        model_flops_global / total_hlo_flops if total_hlo_flops > 0 else 0.0
    )
    bound = max(compute_s, memory_s, collective_s)
    # fraction of roofline: useful-model-compute time over the dominant term
    model_compute_s = (
        model_flops_global / n_devices / PEAK_FLOPS if n_devices else 0.0
    )
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "useful_flops_ratio": useful,
        "model_compute_s_per_device": model_compute_s,
        "roofline_fraction": (model_compute_s / bound) if bound > 0 else 0.0,
        "arithmetic_intensity": (
            flops_per_device / bytes_per_device if bytes_per_device else 0.0
        ),
    }


# --------------------------------------------------------------------------
# MODEL_FLOPS
# --------------------------------------------------------------------------

def param_counts(cfg: ModelConfig) -> dict[str, float]:
    """Analytic total + active param counts (embeddings excluded from the
    6·N·D convention; MoE active = shared + top_k experts)."""
    d = cfg.d_model
    n_attn_per_layer = 0.0
    if cfg.use_mla:
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        h = cfg.n_heads
        q = (
            d * cfg.q_lora_rank + cfg.q_lora_rank * h * (dn + dr)
            if cfg.q_lora_rank
            else d * h * (dn + dr)
        )
        kv = d * (cfg.kv_lora_rank + dr) + cfg.kv_lora_rank * h * (dn + dv)
        o = h * dv * d
        n_attn_per_layer = q + kv + o
    else:
        n_attn_per_layer = (
            d * cfg.n_heads * cfg.head_dim * 2
            + d * cfg.n_kv_heads * cfg.head_dim * 2
        )

    def ffn_params(m):
        return 3 * d * m

    n_layers = cfg.n_layers
    total = 0.0
    active = 0.0
    for i in range(n_layers):
        kind = cfg.layer_kind(i)
        if kind == "recurrent":
            w = cfg.lru_width
            hd = w // cfg.n_heads
            mix = 2 * d * w + cfg.conv_width * w + 2 * cfg.n_heads * hd * hd \
                + w * d
            total += mix
            active += mix
        elif kind == "rwkv":
            mix = 4 * d * d + d * d + 2 * d * 64  # r,k,v,g,o + decay lora
            cm = 2 * d * cfg.d_ff + d * d
            total += mix + cm
            active += mix + cm
            continue  # rwkv blocks carry their own ffn (channel mix)
        else:
            total += n_attn_per_layer
            active += n_attn_per_layer
        # FFN / MoE
        if cfg.n_experts > 0 and i >= cfg.first_dense_layers:
            e_p = ffn_params(cfg.moe_d_ff)
            total += cfg.n_experts * e_p + d * cfg.n_experts
            active += (cfg.top_k + cfg.n_shared_experts) * e_p
        elif kind != "rwkv":
            m = cfg.dense_d_ff if (
                cfg.n_experts > 0 and i < cfg.first_dense_layers
            ) else cfg.d_ff
            total += ffn_params(m)
            active += ffn_params(m)
    if cfg.is_encdec:
        enc = cfg.n_enc_layers * (n_attn_per_layer + ffn_params(cfg.d_ff))
        cross = cfg.n_layers * n_attn_per_layer
        total += enc + cross
        active += enc + cross
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return {
        "total": total, "active": active, "embedding": emb,
        "total_with_emb": total + emb,
    }


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·D (train) or 2·N·D (prefill/decode), N = active non-emb params."""
    n = param_counts(cfg)["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n * tokens
