"""Serving launcher: batched request serving with continuous batching.

``python -m repro.launch.serve --arch <id> --requests 8``
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.config import ServeConfig, get_arch
from repro.models import build_model
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    eng = ServeEngine(
        model, params,
        ServeConfig(max_batch=args.slots, max_seq=args.max_seq),
    )
    rng = np.random.default_rng(args.seed)
    rids = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=rng.integers(4, 16))
        rids.append(eng.submit(prompt, max_new=args.max_new))
    results = eng.run()
    for rid in rids:
        print(f"[serve] request {rid}: {results[rid]}")
    print(f"[serve] completed {len(results)}/{args.requests} requests")


if __name__ == "__main__":
    main()
