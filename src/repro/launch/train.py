"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a real (small-scale, CPU-friendly by default) training job with the
full production stack: jitted train step, checkpointing, restart, sim-token
or synthetic data. On a TPU cluster the same entry point takes
``--production-mesh`` and shards per repro.sharding.
"""

from __future__ import annotations

import argparse

import jax

from repro.config import TrainConfig, get_arch
from repro.core.scenario import SimConfig
from repro.data import sim_token_batches, synthetic_batches
from repro.models import build_model
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full-size", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--data", choices=["sim", "synthetic"], default="sim")
    ap.add_argument("--shard-dir", default=None,
                    help="train on a sharded Phase-III dataset directory "
                         "(written by repro.launch.sweep --dataset-dir)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--d-model", type=int, default=None,
                    help="override reduced d_model (e.g. ~100M params)")
    ap.add_argument("--n-layers", type=int, default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        over = {}
        if args.d_model:
            over = dict(
                d_model=args.d_model, n_heads=max(args.d_model // 64, 1),
                n_kv_heads=max(args.d_model // 64, 1), head_dim=64,
                d_ff=args.d_model * 4, lru_width=args.d_model,
                vocab_size=2048,
            )
        if args.n_layers:
            pat = len(cfg.layer_pattern)
            over["n_layers"] = (args.n_layers // pat) * pat or pat
        cfg = cfg.reduced(**over)
    model = build_model(cfg)

    tc = TrainConfig(
        learning_rate=args.lr,
        warmup_steps=max(args.steps // 20, 1),
        total_steps=args.steps,
        microbatches=args.microbatches,
        remat=args.remat,
    )
    if args.data == "sim":
        data = sim_token_batches(
            cfg, SimConfig(n_slots=32), batch=args.batch, seq=args.seq,
            shard_dir=args.shard_dir,
        )
    else:
        data = synthetic_batches(cfg, batch=args.batch, seq=args.seq)

    print(f"[train] arch={cfg.name} devices={jax.devices()}")
    trainer = Trainer(model, tc, data, ckpt_dir=args.ckpt_dir)
    trainer.run(steps=args.steps)


if __name__ == "__main__":
    main()
