"""Trip-count-honest cost accounting for the roofline.

XLA's ``cost_analysis()`` counts a ``while``-loop (lax.scan) body ONCE —
verified empirically in EXPERIMENTS.md §Dry-run — so naively reading the
official scanned-stack compile would undercount a 42-layer model 42×. The
coster therefore lowers *python-unrolled* reduced variants and extrapolates,
which is exact because every cost component is affine in the two trip counts:

    train:      cost(nb, mb) = U(nb) + mb · G(nb)
                (U = optimizer update etc., G = per-microbatch fwd+bwd;
                 both affine in the block count nb)
    inference:  cost(nb)     = A + nb · L

Four lowered points {(nb,mb)} = {(1,1),(2,1),(1,2),(2,2)} pin down the train
form; two points pin down inference. Every variant is lowered with the SAME
mesh and shardings as the official cell, so collective wire bytes
extrapolate identically.

Remaining while-loops inside a block (the RWKV6 time-mix scan) get an
analytic correction (flops + HBM bytes for the (S−1) uncounted steps);
RG-LRU uses ``associative_scan`` (log-depth, fully counted) so it needs none.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax

from repro.config.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import build_model
from repro.models.lm import stack_plan
from repro.sharding import (
    batch_shardings,
    cache_shardings,
    opt_state_shardings,
    param_shardings,
)
from repro.launch.specs import input_specs
from repro.train.step import abstract_train_state, make_train_step
from repro.utils.hlo import collective_bytes


class CostVec(NamedTuple):
    flops: float
    bytes: float
    wire: float

    def __add__(self, o):
        return CostVec(self.flops + o.flops, self.bytes + o.bytes,
                       self.wire + o.wire)

    def __sub__(self, o):
        return CostVec(self.flops - o.flops, self.bytes - o.bytes,
                       self.wire - o.wire)

    def scale(self, a: float):
        return CostVec(self.flops * a, self.bytes * a, self.wire * a)


def _variant_cfg(cfg: ModelConfig, nb: int) -> ModelConfig:
    plan = stack_plan(cfg)
    n_layers = cfg.first_dense_layers + nb * cfg.pattern_len + len(plan.tail)
    upd = {"n_layers": n_layers}
    if cfg.is_encdec:
        upd["n_enc_layers"] = nb
        upd["n_layers"] = nb
    return dataclasses.replace(cfg, **upd)


def _lower_cost(fn, args, mesh) -> CostVec:
    from repro.sharding.ctx import activation_sharding

    with mesh, activation_sharding(mesh):
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):     # older JAX: one dict per program
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    return CostVec(
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        float(coll.total_wire_bytes),
    )


def _build_variant(cfg, shape: ShapeConfig, mesh, kind: str,
                   tc: TrainConfig | None, mb: int):
    model = build_model(cfg)
    specs = input_specs(cfg, shape)

    if kind == "train":
        vtc = dataclasses.replace(tc, microbatches=mb)
        params, opt_state = abstract_train_state(model, vtc)
        p_sh = param_shardings(cfg, params, mesh)
        o_sh = opt_state_shardings(cfg, opt_state, params, mesh)
        b_sh = batch_shardings(mesh, specs)
        fn = jax.jit(
            make_train_step(model, vtc, unroll=True),
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
        )
        return fn, (params, opt_state, specs)

    params = model.abstract_params()
    p_sh = param_shardings(cfg, params, mesh)
    if kind == "prefill":
        cache = model.abstract_cache(shape.global_batch, shape.seq_len)
        c_sh = cache_shardings(mesh, cache, shape.global_batch)
        b_sh = batch_shardings(mesh, specs)
        fn = jax.jit(
            lambda p, c, b: model.prefill(p, c, b, unroll=True),
            in_shardings=(p_sh, c_sh, b_sh),
            out_shardings=(None, c_sh),
        )
        return fn, (params, cache, specs)

    cache = specs["cache"]
    c_sh = cache_shardings(mesh, cache, shape.global_batch)
    tok_sh = batch_shardings(mesh, {"t": specs["tokens"]})["t"]
    pos_sh = batch_shardings(mesh, {"t": specs["pos"]})["t"]
    fn = jax.jit(
        lambda p, c, t, q: model.decode(p, c, t, q, unroll=True),
        in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
        out_shardings=(None, c_sh),
    )
    return fn, (params, cache, specs["tokens"], specs["pos"])


def _wkv_correction(
    cfg: ModelConfig, shape: ShapeConfig, n_devices: int, kind: str
) -> CostVec:
    """Uncounted RWKV6 time-scan steps: analytic flops/bytes (global/chips).

    Per step/layer/row/head: kv outer (2·K·V) + readout (2·K·V) + state
    decay-update (2·K·V) ≈ 6·K·V flops; HBM traffic for the streamed
    r,k,v,w inputs (state stays kernel-resident on TPU).
    """
    if cfg.family != "rwkv" or kind == "decode":
        return CostVec(0.0, 0.0, 0.0)
    s = shape.seq_len
    b = shape.global_batch
    hd = cfg.rwkv_head_dim
    h = cfg.d_model // hd
    steps_missing = (s - 1) * b * h * cfg.n_layers
    flops = steps_missing * 6.0 * hd * hd
    bytes_ = steps_missing * (4 * hd) * 2.0  # r,k,v,w rows, bf16
    return CostVec(flops / n_devices, bytes_ / n_devices, 0.0)


def extrapolated_costs(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    tc: TrainConfig | None,
) -> dict:
    """Per-device (flops, bytes, wire) for the FULL cell via affine
    extrapolation over unrolled reduced variants."""
    plan = stack_plan(cfg)
    nb_full = cfg.n_layers if cfg.is_encdec else plan.n_blocks
    kind = shape.kind
    n_dev = mesh.devices.size

    # block-count sample points: 2 and 4 (nb=1 graphs are degenerate enough
    # that XLA sometimes picks different layouts, breaking affinity)
    nb_lo, nb_hi = (2, 4) if nb_full >= 4 else (1, 2)
    span = nb_hi - nb_lo

    if kind == "train":
        mb_full = tc.microbatches
        # all variants see one official-sized microbatch
        vshape = dataclasses.replace(
            shape, global_batch=max(shape.global_batch // mb_full, 1)
        )
        pts = {}
        for nb in (nb_lo, nb_hi):
            for mb in (1, 2):
                vcfg = _variant_cfg(cfg, nb)
                vsh = (
                    dataclasses.replace(
                        vshape, global_batch=vshape.global_batch * mb
                    )
                    if mb > 1 else vshape
                )
                fn, args = _build_variant(vcfg, vsh, mesh, kind, tc, mb)
                pts[(nb, mb)] = _lower_cost(fn, args, mesh)
        g_lo = pts[(nb_lo, 2)] - pts[(nb_lo, 1)]
        g_hi = pts[(nb_hi, 2)] - pts[(nb_hi, 1)]
        u_lo = pts[(nb_lo, 1)] - g_lo
        u_hi = pts[(nb_hi, 1)] - g_hi
        g = g_lo + (g_hi - g_lo).scale((nb_full - nb_lo) / span)
        u = u_lo + (u_hi - u_lo).scale((nb_full - nb_lo) / span)
        total = u + g.scale(mb_full)
        floor = pts[(nb_lo, 1)]
    else:
        c_lo = _lower_cost(
            *_build_variant(_variant_cfg(cfg, nb_lo), shape, mesh, kind,
                            tc, 1),
            mesh,
        )
        c_hi = _lower_cost(
            *_build_variant(_variant_cfg(cfg, nb_hi), shape, mesh, kind,
                            tc, 1),
            mesh,
        )
        total = c_lo + (c_hi - c_lo).scale((nb_full - nb_lo) / span)
        floor = c_lo

    # extrapolation sanity floor: the full model can never cost less than
    # its smallest lowered variant (guards against layout-choice noise)
    total = CostVec(
        max(total.flops, floor.flops),
        max(total.bytes, floor.bytes),
        max(total.wire, floor.wire),
    )
    total = total + _wkv_correction(cfg, shape, n_dev, kind)
    return {
        "flops_per_device": total.flops,
        "bytes_per_device": total.bytes,
        "wire_bytes_per_device": total.wire,
        "method": "2-point-affine-extrapolation(unrolled variants)",
    }
