"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import; smoke
tests and benches must keep seeing 1 device).
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod (TPU v5e); 2 pods adds the leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(max_workers: int | None = None):
    """Available devices as a 1-D 'workers' mesh (sweeps, examples).

    ``max_workers`` caps the worker count (uses the first k devices) so a
    launcher's ``--workers`` flag actually sizes the mesh the sweep runs
    on, not just its failure-injection bookkeeping.
    """
    devs = list(jax.devices())
    if max_workers is not None:
        devs = devs[: max(1, min(max_workers, len(devs)))]
    return jax.sharding.Mesh(np.asarray(devs), ("workers",))


def make_abstract_mesh(shape, axes):
    """Device-free mesh for spec validation (tests, dry-run planning).

    ``jax.sharding.AbstractMesh`` changed signature across JAX releases:
    older versions took ``(shape, axis_names)``, current ones take a single
    ``((name, size), ...)`` tuple. Accept the classic (shape, axes) form and
    build whichever the installed JAX wants.
    """
    try:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))
