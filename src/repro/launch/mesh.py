"""Mesh construction + host-platform device forcing — the sharding layer.

Everything here is a FUNCTION, not a module-level constant: importing this
module never touches jax device state (the dry-run sets XLA_FLAGS before
any jax import; smoke tests and benches must keep seeing the default
device set).

The sweep executor (:mod:`repro.core.sweep`) is written against an
abstract 1-D instance mesh, so the same code path covers:

- one CPU process pretending to be N devices
  (``XLA_FLAGS=--xla_force_host_platform_device_count=N``, or the
  launcher's ``--devices N`` which sets it for you —
  :func:`force_host_device_count`), the paper's "multiple instances per
  node" on a laptop;
- a real multi-device host (N GPUs / TPU chips): identical code, real
  parallel speedup.
"""

from __future__ import annotations

import os

import jax
import numpy as np

_FORCE_FLAG = "--xla_force_host_platform_device_count"


def force_host_device_count(n: int) -> None:
    """Make the CPU backend expose ``n`` devices (simulated-device mode).

    Rewrites the ``XLA_FLAGS`` env var, replacing any existing
    ``--xla_force_host_platform_device_count`` setting. MUST run before
    jax initializes its backends (i.e. before the first array op or
    ``jax.devices()`` call — merely importing jax is fine); afterwards the
    flag is silently ignored by XLA, so launchers call this from argv
    pre-parsing before importing anything heavy
    (see :mod:`repro.launch.sweep`). Affects only the host (CPU) platform;
    harmless on real accelerator backends.
    """
    if n < 1:
        raise ValueError(f"device count must be >= 1, got {n}")
    flags = [
        f for f in os.environ.get("XLA_FLAGS", "").split()
        if not f.startswith(_FORCE_FLAG)
    ]
    flags.append(f"{_FORCE_FLAG}={n}")
    os.environ["XLA_FLAGS"] = " ".join(flags)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod (TPU v5e); 2 pods adds the leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(max_workers: int | None = None):
    """Available devices as a 1-D 'workers' mesh — the sweep mesh.

    ``max_workers`` caps the device count (uses the first k devices) so a
    launcher's ``--devices`` flag actually sizes the mesh the sweep runs
    on, not just its failure-injection bookkeeping. Raises when more
    devices are requested than the backend exposes (on CPU, call
    :func:`force_host_device_count` before jax initializes — the
    launcher's ``--devices`` does).
    """
    devs = list(jax.devices())
    if max_workers is not None:
        if max_workers > len(devs):
            raise ValueError(
                f"{max_workers} devices requested but only {len(devs)} "
                f"available — on CPU, force more with "
                f"XLA_FLAGS={_FORCE_FLAG}=N (or the sweep launcher's "
                f"--devices N) before jax initializes"
            )
        devs = devs[: max(1, max_workers)]
    return jax.sharding.Mesh(np.asarray(devs), ("workers",))


def instance_sharding(mesh):
    """The sweep's canonical sharding: instance axis over every mesh axis.

    Re-exported from :mod:`repro.core.sweep` so launchers and benchmarks
    can place arrays the way the executor expects without importing core
    internals.
    """
    from repro.core.sweep import instance_sharding as _impl

    return _impl(mesh)


def make_abstract_mesh(shape, axes):
    """Device-free mesh for spec validation (tests, dry-run planning).

    ``jax.sharding.AbstractMesh`` changed signature across JAX releases:
    older versions took ``(shape, axis_names)``, current ones take a single
    ``((name, size), ...)`` tuple. Accept the classic (shape, axes) form and
    build whichever the installed JAX wants.
    """
    try:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))
