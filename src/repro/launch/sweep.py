"""Sweep launcher — the paper's headline workload as one command.

``python -m repro.launch.sweep --instances 48 --steps 1200`` reproduces the
paper's 6-node × 8-instance batch (at CPU-friendly horizons), with optional
failure injection and checkpointing:

``python -m repro.launch.sweep --instances 48 --fail-prob 0.1 --ckpt-dir /tmp/sw``
"""

from __future__ import annotations

import argparse
import json
import time

from repro.ckpt import CheckpointManager
from repro.core.aggregate import aggregate_metrics, metrics_to_records
from repro.core.fault import FailureInjector, run_with_failures
from repro.core.scenario import SimConfig
from repro.core.sweep import SweepConfig, SweepRunner
from repro.launch.mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--instances", type=int, default=48)
    ap.add_argument("--steps", type=int, default=1200)
    ap.add_argument("--chunk-steps", type=int, default=400)
    ap.add_argument("--slots", type=int, default=32)
    ap.add_argument("--neighbor-impl", default="sort",
                    choices=["reference", "dense", "sort", "pallas"],
                    help="neighborhood engine implementation")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--vary-horizon", action="store_true")
    ap.add_argument("--fail-prob", type=float, default=0.0)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--out", default=None, help="write records JSON here")
    args = ap.parse_args()

    cfg = SweepConfig(
        n_instances=args.instances,
        steps_per_instance=args.steps,
        chunk_steps=args.chunk_steps,
        sim=SimConfig(n_slots=args.slots, neighbor_impl=args.neighbor_impl),
        seed=args.seed,
        vary_horizon=args.vary_horizon,
    )
    runner = SweepRunner(cfg, mesh=make_host_mesh())
    injector = FailureInjector.random(
        n_workers=args.workers,
        n_chunks=max(args.steps // args.chunk_steps * 3, 8),
        fail_prob=args.fail_prob,
        seed=args.seed,
    )
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    t0 = time.perf_counter()
    state, info = run_with_failures(
        runner, injector, ckpt=ckpt,
        on_progress=lambda c, done: print(
            f"[sweep] chunk {c}: {done*100:.1f}% complete"
        ),
    )
    dt = time.perf_counter() - t0
    summary = aggregate_metrics(state.metrics)
    print(f"[sweep] done in {dt:.1f}s — completion "
          f"{info['completion_rate']*100:.0f}%, "
          f"{info['chunks_run']} chunks, "
          f"{len(info['failure_events'])} failure events")
    print(f"[sweep] {json.dumps(summary, indent=1)}")
    if args.out:
        records = metrics_to_records(state.metrics, state.params)
        with open(args.out, "w") as f:
            json.dump({"summary": summary, "records": records,
                       "fault_info": info}, f, indent=1)
        print(f"[sweep] wrote dataset to {args.out}")


if __name__ == "__main__":
    main()
