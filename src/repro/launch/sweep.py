"""Sweep launcher — the paper's headline workload as one command.

``python -m repro.launch.sweep --instances 48 --steps 1200`` reproduces the
paper's 6-node × 8-instance batch (at CPU-friendly horizons), with optional
failure injection and checkpointing:

``python -m repro.launch.sweep --instances 48 --fail-prob 0.1 --ckpt-dir /tmp/sw``

Device sharding (the paper's "across an arbitrary number of computing
nodes"): ``--devices N`` sizes the 1-D device mesh the instance axis is
sharded over. On a CPU host it also *simulates* N devices by setting
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax
initializes — same code path as a real N-accelerator host. ``--workers W``
is the per-device instance granularity (the paper's instances-per-node),
so the fault injector models an ``N × W`` grid and the planner pads each
device block to a multiple of W:

``python -m repro.launch.sweep --devices 4 --workers 8 --instances 32``

``--pipeline`` (default on) overlaps host I/O — checkpoint writes, dataset
shard compression — with device compute by deferring chunk c's file I/O
until chunk c+1 has been dispatched; ``--no-pipeline`` forces the fully
synchronous loop (bit-for-bit identical output either way).

Scenario selection (the registry catalog, ``repro.core.scenarios``):

``python -m repro.launch.sweep --scenario lane_drop``
    every instance runs the lane-drop bottleneck;
``python -m repro.launch.sweep --scenario-mix highway_merge,stop_and_go``
    instances are assigned the listed scenarios round-robin;
``python -m repro.launch.sweep --scenario-mix all``
    round-robin over every registered scenario.

Mixed-sweep dispatch (``--dispatch``, default ``auto``): ``grouped`` repacks
instances per scenario into dense switch-free compiled calls each chunk
(~k× faster on a k-scenario mix; on a multi-device mesh the groups are
LPT-packed into per-device blocks instead); ``switch`` keeps the
single-compile vmapped ``lax.switch`` program; ``auto`` picks grouped
whenever the roster is mixed. All modes are bit-for-bit
trajectory-equivalent.

Phase-III dataset output (``--dataset-dir``): turns on trajectory recording
(``repro.core.record``) and streams every finished instance's time series +
token stream into npz/jsonl shards with a manifest
(``repro.data.shards.DatasetWriter``) — the ML-ready replacement for the
old single monolithic records JSON (``--out`` still writes the summary
digest):

``python -m repro.launch.sweep --scenario-mix all --dataset-dir /tmp/ds``

Unattended-run supervision (the paper's §5.2 completion contract,
``repro.core.fleet``): the loop is always the supervised one — failed
instances are charged against a per-instance retry budget
(``--max-retries``) with exponential re-queue backoff, poison instances
are quarantined instead of thrashing the fleet, and every event lands in
an append-only run journal (``--journal``, defaulting to
``<ckpt-dir>/journal.jsonl``). ``--hang-prob`` and ``--poison`` extend
the injected fault taxonomy beyond crashes; ``--chunk-deadline`` journals
wall-clock overruns; ``--heartbeat-file`` makes the worker emit atomic
liveness beacons for the process supervisor
(``python -m repro.launch.controller``), which SIGKILLs and resumes a
stalled worker:

``python -m repro.launch.sweep --fail-prob 0.1 --max-retries 3 \\
    --chunk-deadline 60 --ckpt-dir /tmp/sw``
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _write_heartbeat(path: str, chunk: int, done: float) -> None:
    """Atomically publish a liveness beacon (tmp + rename, never torn).

    The process controller (``repro.launch.controller``) polls this file's
    payload; a stale ``time`` means the worker is hung and gets SIGKILLed.
    """
    tmp = f"{path}.tmp-{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump({"chunk": chunk, "done": done, "time": time.time()}, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _preparse_devices(argv: list[str]) -> int | None:
    """Extract ``--devices N`` from argv WITHOUT importing jax.

    ``--xla_force_host_platform_device_count`` only works before the
    backend initializes, so the launcher must set it before the real
    argparse run (whose ``choices=list_scenarios()`` pulls in jax). Only
    the exact ``--devices``/``--devices=`` spellings match — the real
    parser runs with ``allow_abbrev=False`` so no other spelling is
    accepted there either — and malformed values are left for argparse to
    reject with a proper usage error.
    """
    for i, a in enumerate(argv):
        value = None
        if a == "--devices" and i + 1 < len(argv):
            value = argv[i + 1]
        elif a.startswith("--devices="):
            value = a.split("=", 1)[1]
        if value is not None:
            try:
                return int(value)
            except ValueError:
                return None  # argparse prints the clean error
    return None


def main() -> None:
    devices = _preparse_devices(sys.argv[1:])
    if devices is not None and devices >= 1 and "jax" not in sys.modules:
        from repro.launch.mesh import force_host_device_count

        force_host_device_count(devices)

    # heavy imports AFTER the device-count flag is in place
    from repro.ckpt import CheckpointManager
    from repro.core.aggregate import aggregate_metrics, metrics_to_records
    from repro.core.fault import FaultModel
    from repro.core.fleet import (
        RetryPolicy,
        RunJournal,
        format_completion_table,
        run_supervised,
    )
    from repro.core.record import RecordConfig
    from repro.core.scenario import SimConfig
    from repro.core.scenarios import list_scenarios
    from repro.core.sweep import SweepConfig, SweepRunner
    from repro.data.shards import DatasetWriter
    from repro.launch.mesh import make_host_mesh

    # allow_abbrev off: the --devices pre-parse above matches exact
    # spellings only, so abbreviations must not silently bypass it
    ap = argparse.ArgumentParser(allow_abbrev=False)
    ap.add_argument("--instances", type=int, default=48)
    ap.add_argument("--steps", type=int, default=1200)
    ap.add_argument("--chunk-steps", type=int, default=400)
    ap.add_argument("--slots", type=int, default=32)
    ap.add_argument("--scenario", default="highway_merge",
                    choices=list_scenarios(),
                    help="workload every instance runs (registry name)")
    ap.add_argument("--scenario-mix", default=None,
                    help="comma-separated scenario names assigned to "
                         "instances round-robin, or 'all' for the whole "
                         "registry (overrides --scenario)")
    ap.add_argument("--dispatch", default="auto",
                    choices=["auto", "switch", "grouped"],
                    help="mixed-sweep chunk dispatch: grouped = per-scenario "
                         "repacked compiled calls (no lax.switch tax), "
                         "switch = one vmapped-switch compile, auto = "
                         "grouped iff the scenario roster is mixed")
    ap.add_argument("--neighbor-impl", default="sort",
                    choices=["reference", "dense", "sort", "pallas"],
                    help="neighborhood engine implementation")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--vary-horizon", action="store_true")
    ap.add_argument("--fail-prob", type=float, default=0.0,
                    help="per-worker per-chunk probability of an injected "
                         "crash (chunk progress lost, instances reverted "
                         "and re-queued)")
    ap.add_argument("--hang-prob", type=float, default=0.0,
                    help="per-worker per-chunk probability of an injected "
                         "hang (deadline timeout: same revert as a crash, "
                         "distinct journal event)")
    ap.add_argument("--straggler-prob", type=float, default=0.0,
                    help="per-worker per-chunk probability of a journaled "
                         "slow-but-successful chunk (results kept)")
    ap.add_argument("--poison", default="",
                    help="comma-separated logical instance ids that crash "
                         "every chunk they run — exhausts the retry budget "
                         "and exercises quarantine")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="per-instance retry budget: an instance failing "
                         "more than this many times is quarantined "
                         "(excluded from scheduling and from the eligible "
                         "completion denominator)")
    ap.add_argument("--chunk-deadline", type=float, default=None,
                    help="wall-clock seconds per chunk before a 'deadline' "
                         "event is journaled (hard hangs are killed by the "
                         "controller's heartbeat timeout)")
    ap.add_argument("--heartbeat-file", default=None,
                    help="write an atomic {chunk, done, time} liveness "
                         "beacon here after every committed chunk (the "
                         "controller's hang detector)")
    ap.add_argument("--journal", default=None,
                    help="append-only jsonl run journal (default: "
                         "<ckpt-dir>/journal.jsonl when --ckpt-dir is set)")
    ap.add_argument("--devices", type=int, default=None,
                    help="device-mesh size the instance axis is sharded "
                         "over (default: all visible devices); on CPU "
                         "also forces that many simulated host devices")
    ap.add_argument("--workers", type=int, default=1,
                    help="instances per device (the paper's per-node "
                         "parallelism): failure injection models a "
                         "devices x workers grid and device blocks are "
                         "padded to a multiple of this")
    ap.add_argument("--pipeline", dest="pipeline", action="store_true",
                    default=True,
                    help="overlap host I/O (checkpoints, dataset shards) "
                         "with device compute (default)")
    ap.add_argument("--no-pipeline", dest="pipeline", action="store_false",
                    help="fully synchronous chunk loop (same bits, "
                         "no overlap)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--out", default=None, help="write records JSON here")
    ap.add_argument("--dataset-dir", default=None,
                    help="stream a sharded Phase-III dataset here "
                         "(npz/jsonl shards + manifest); implies recording")
    ap.add_argument("--record-every", type=int, default=0,
                    help="trajectory recording stride in steps (0 = off; "
                         "--dataset-dir defaults it to 10)")
    ap.add_argument("--record-slots", type=int, default=8,
                    help="vehicle slots recorded for token streams")
    ap.add_argument("--shard-size", type=int, default=16,
                    help="instances per dataset shard")
    args = ap.parse_args()
    if args.workers < 1:
        ap.error("--workers must be >= 1 (instances per device)")
    if args.devices is not None and args.devices < 1:
        ap.error("--devices must be >= 1")

    record_every = args.record_every
    if args.dataset_dir and record_every == 0:
        record_every = 10
    record = (
        RecordConfig(record_every=record_every, k_slots=args.record_slots)
        if record_every > 0
        else None
    )

    if args.scenario_mix:
        mix = (
            tuple(list_scenarios())
            if args.scenario_mix.strip() == "all"
            else tuple(s.strip() for s in args.scenario_mix.split(",") if s.strip())
        )
    else:
        mix = ()

    cfg = SweepConfig(
        n_instances=args.instances,
        steps_per_instance=args.steps,
        chunk_steps=args.chunk_steps,
        sim=SimConfig(n_slots=args.slots, neighbor_impl=args.neighbor_impl,
                      scenario=args.scenario),
        seed=args.seed,
        vary_horizon=args.vary_horizon,
        scenario_mix=mix,
        dispatch=args.dispatch,
        record=record,
    )
    # the mesh is the source of truth for device count; --workers adds the
    # per-device instance granularity, and the injector models the full
    # devices x workers worker grid (the paper's nodes x instances-per-node)
    mesh = make_host_mesh(max_workers=args.devices)
    runner = SweepRunner(cfg, mesh=mesh, workers_per_device=args.workers)
    n_devices = int(mesh.devices.size)
    n_workers = runner._n_workers()
    try:
        poison = tuple(
            int(p) for p in args.poison.split(",") if p.strip()
        )
    except ValueError:
        ap.error("--poison takes comma-separated integer instance ids")
    faults = FaultModel.random_model(
        n_workers=n_workers,
        n_chunks=max(args.steps // args.chunk_steps * 3, 8),
        fail_prob=args.fail_prob,
        hang_prob=args.hang_prob,
        straggler_prob=args.straggler_prob,
        poison_instances=poison,
        seed=args.seed,
    )
    policy = RetryPolicy(max_retries=args.max_retries)
    journal_path = args.journal or (
        os.path.join(args.ckpt_dir, "journal.jsonl")
        if args.ckpt_dir else None
    )
    journal = RunJournal(journal_path) if journal_path else None
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    writer = (
        DatasetWriter(args.dataset_dir, cfg, shard_size=args.shard_size)
        if args.dataset_dir
        else None
    )

    print(f"[sweep] scenarios: {', '.join(cfg.scenarios)} "
          f"({'mixed round-robin' if len(cfg.scenarios) > 1 else 'uniform'}) "
          f"| dispatch {cfg.effective_dispatch} "
          f"| {n_devices} device(s) x {args.workers} worker(s) "
          f"| {'pipelined' if args.pipeline else 'synchronous'} I/O"
          + (f" | recording every {record_every} steps" if record else ""))
    def on_progress(c: int, done: float) -> None:
        print(f"[sweep] chunk {c}: {done*100:.1f}% complete")
        if args.heartbeat_file:
            _write_heartbeat(args.heartbeat_file, c, done)

    t0 = time.perf_counter()
    state, info = run_supervised(
        runner, faults, policy=policy, ckpt=ckpt, writer=writer,
        journal=journal, pipeline=args.pipeline,
        chunk_deadline=args.chunk_deadline, on_progress=on_progress,
    )
    dt = time.perf_counter() - t0
    summary = aggregate_metrics(
        state.metrics, scenario_ids=state.scenario_id,
        scenario_names=cfg.scenarios,
    )
    print(f"[sweep] done in {dt:.1f}s — completion "
          f"{info['completion_rate']*100:.0f}% "
          f"(eligible {info['eligible_completion_rate']*100:.0f}%), "
          f"{info['chunks_run']} chunks, "
          f"{len(info['failure_events'])} failure events, "
          f"{len(info['quarantined'])} quarantined")
    print(format_completion_table(info["report"]))
    print(f"[sweep] {json.dumps(summary, indent=1)}")
    if writer is not None:
        manifest = writer.finalize(summary=summary, fault_info=info)
        print(f"[sweep] wrote sharded dataset: {manifest} "
              f"({len(writer.written)} instances)")
    if args.out:
        records = metrics_to_records(
            state.metrics, state.params,
            scenario_ids=state.scenario_id, scenario_names=cfg.scenarios,
        )
        with open(args.out, "w") as f:
            json.dump({"summary": summary, "records": records,
                       "fault_info": info}, f, indent=1)
        print(f"[sweep] wrote dataset to {args.out}")


if __name__ == "__main__":
    main()
