"""Sweep launcher — the paper's headline workload as one command.

``python -m repro.launch.sweep --instances 48 --steps 1200`` reproduces the
paper's 6-node × 8-instance batch (at CPU-friendly horizons), with optional
failure injection and checkpointing:

``python -m repro.launch.sweep --instances 48 --fail-prob 0.1 --ckpt-dir /tmp/sw``

Scenario selection (the registry catalog, ``repro.core.scenarios``):

``python -m repro.launch.sweep --scenario lane_drop``
    every instance runs the lane-drop bottleneck;
``python -m repro.launch.sweep --scenario-mix highway_merge,stop_and_go``
    instances are assigned the listed scenarios round-robin;
``python -m repro.launch.sweep --scenario-mix all``
    round-robin over every registered scenario.

Mixed-sweep dispatch (``--dispatch``, default ``auto``): ``grouped`` repacks
instances per scenario into dense switch-free compiled calls each chunk
(~k× faster on a k-scenario mix); ``switch`` keeps the single-compile
vmapped ``lax.switch`` program; ``auto`` picks grouped whenever the roster
is mixed. Both modes are bit-for-bit trajectory-equivalent.

Phase-III dataset output (``--dataset-dir``): turns on trajectory recording
(``repro.core.record``) and streams every finished instance's time series +
token stream into npz/jsonl shards with a manifest
(``repro.data.shards.DatasetWriter``) — the ML-ready replacement for the
old single monolithic records JSON (``--out`` still writes the summary
digest):

``python -m repro.launch.sweep --scenario-mix all --dataset-dir /tmp/ds``
"""

from __future__ import annotations

import argparse
import json
import time

from repro.ckpt import CheckpointManager
from repro.core.aggregate import aggregate_metrics, metrics_to_records
from repro.core.fault import FailureInjector, run_with_failures
from repro.core.record import RecordConfig
from repro.core.scenario import SimConfig
from repro.core.scenarios import list_scenarios
from repro.core.sweep import SweepConfig, SweepRunner
from repro.data.shards import DatasetWriter
from repro.launch.mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--instances", type=int, default=48)
    ap.add_argument("--steps", type=int, default=1200)
    ap.add_argument("--chunk-steps", type=int, default=400)
    ap.add_argument("--slots", type=int, default=32)
    ap.add_argument("--scenario", default="highway_merge",
                    choices=list_scenarios(),
                    help="workload every instance runs (registry name)")
    ap.add_argument("--scenario-mix", default=None,
                    help="comma-separated scenario names assigned to "
                         "instances round-robin, or 'all' for the whole "
                         "registry (overrides --scenario)")
    ap.add_argument("--dispatch", default="auto",
                    choices=["auto", "switch", "grouped"],
                    help="mixed-sweep chunk dispatch: grouped = per-scenario "
                         "repacked compiled calls (no lax.switch tax), "
                         "switch = one vmapped-switch compile, auto = "
                         "grouped iff the scenario roster is mixed")
    ap.add_argument("--neighbor-impl", default="sort",
                    choices=["reference", "dense", "sort", "pallas"],
                    help="neighborhood engine implementation")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--vary-horizon", action="store_true")
    ap.add_argument("--fail-prob", type=float, default=0.0)
    ap.add_argument("--workers", type=int, default=None,
                    help="cap the worker-mesh size (default: all devices); "
                         "failure injection is sized from the actual mesh")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--out", default=None, help="write records JSON here")
    ap.add_argument("--dataset-dir", default=None,
                    help="stream a sharded Phase-III dataset here "
                         "(npz/jsonl shards + manifest); implies recording")
    ap.add_argument("--record-every", type=int, default=0,
                    help="trajectory recording stride in steps (0 = off; "
                         "--dataset-dir defaults it to 10)")
    ap.add_argument("--record-slots", type=int, default=8,
                    help="vehicle slots recorded for token streams")
    ap.add_argument("--shard-size", type=int, default=16,
                    help="instances per dataset shard")
    args = ap.parse_args()

    record_every = args.record_every
    if args.dataset_dir and record_every == 0:
        record_every = 10
    record = (
        RecordConfig(record_every=record_every, k_slots=args.record_slots)
        if record_every > 0
        else None
    )

    if args.scenario_mix:
        mix = (
            tuple(list_scenarios())
            if args.scenario_mix.strip() == "all"
            else tuple(s.strip() for s in args.scenario_mix.split(",") if s.strip())
        )
    else:
        mix = ()

    cfg = SweepConfig(
        n_instances=args.instances,
        steps_per_instance=args.steps,
        chunk_steps=args.chunk_steps,
        sim=SimConfig(n_slots=args.slots, neighbor_impl=args.neighbor_impl,
                      scenario=args.scenario),
        seed=args.seed,
        vary_horizon=args.vary_horizon,
        scenario_mix=mix,
        dispatch=args.dispatch,
        record=record,
    )
    # the mesh is the source of truth for worker count: --workers sizes the
    # mesh, and the injector is sized from whatever mesh actually exists
    mesh = make_host_mesh(max_workers=args.workers)
    runner = SweepRunner(cfg, mesh=mesh)
    n_workers = int(mesh.devices.size)
    injector = FailureInjector.random(
        n_workers=n_workers,
        n_chunks=max(args.steps // args.chunk_steps * 3, 8),
        fail_prob=args.fail_prob,
        seed=args.seed,
    )
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    writer = (
        DatasetWriter(args.dataset_dir, cfg, shard_size=args.shard_size)
        if args.dataset_dir
        else None
    )

    print(f"[sweep] scenarios: {', '.join(cfg.scenarios)} "
          f"({'mixed round-robin' if len(cfg.scenarios) > 1 else 'uniform'}) "
          f"| dispatch {cfg.effective_dispatch} | {n_workers} worker(s)"
          + (f" | recording every {record_every} steps" if record else ""))
    t0 = time.perf_counter()
    state, info = run_with_failures(
        runner, injector, ckpt=ckpt, writer=writer,
        on_progress=lambda c, done: print(
            f"[sweep] chunk {c}: {done*100:.1f}% complete"
        ),
    )
    dt = time.perf_counter() - t0
    summary = aggregate_metrics(
        state.metrics, scenario_ids=state.scenario_id,
        scenario_names=cfg.scenarios,
    )
    print(f"[sweep] done in {dt:.1f}s — completion "
          f"{info['completion_rate']*100:.0f}%, "
          f"{info['chunks_run']} chunks, "
          f"{len(info['failure_events'])} failure events")
    print(f"[sweep] {json.dumps(summary, indent=1)}")
    if writer is not None:
        manifest = writer.finalize(summary=summary, fault_info=info)
        print(f"[sweep] wrote sharded dataset: {manifest} "
              f"({len(writer.written)} instances)")
    if args.out:
        records = metrics_to_records(
            state.metrics, state.params,
            scenario_ids=state.scenario_id, scenario_names=cfg.scenarios,
        )
        with open(args.out, "w") as f:
            json.dump({"summary": summary, "records": records,
                       "fault_info": info}, f, indent=1)
        print(f"[sweep] wrote dataset to {args.out}")


if __name__ == "__main__":
    main()
