"""Unattended-run process controller: heartbeats, SIGKILL, resume, gate.

The process half of the paper's §5.2 contract (the in-process half is
``repro.core.fleet``): run the sweep as a child worker
(``python -m repro.launch.sweep``) and keep it alive without a human —

- **Heartbeats.** The worker publishes an atomic ``{chunk, done, time}``
  beacon after every committed chunk (``--heartbeat-file``). The
  controller polls it; a beacon older than ``--heartbeat-timeout``
  means the worker is hung (a real hang, not the simulated
  ``FaultModel`` kind) and gets SIGKILLed.
- **Resume.** A killed or crashed worker is respawned with the same
  arguments; the sweep resumes from the last *valid* checkpoint (the
  digest-verified fallback restore in ``repro.ckpt``) and replays its
  fleet state from the run journal. ``--max-worker-restarts`` bounds the
  respawn loop.
- **Chaos mode.** ``--chaos-kills N`` makes the controller itself
  SIGKILL the worker N times after it has made progress
  (``--chaos-min-chunks`` committed chunks since spawn) — the CI smoke
  proof that an unattended run survives real process death, not just
  injected reverts. Chaos kills do not consume the restart budget.
- **Completion gate.** When the worker finally exits 0, the controller
  reads its ``--out`` result JSON and exits 0 only if
  ``eligible_completion_rate == 1.0`` — every instance the fleet kept
  scheduling finished; quarantined instances are reported, not hidden.

The controller is deliberately jax-free (it must stay alive and cheap
while the worker owns the accelerators), so it keeps its own local
journal append rather than importing ``repro.core.fleet``.

Typical invocation (everything after ``--`` goes to the worker verbatim;
the controller appends ``--ckpt-dir``, ``--heartbeat-file`` and
``--out`` itself)::

    python -m repro.launch.controller --ckpt-dir /tmp/run \\
        --chaos-kills 2 -- --instances 8 --steps 200 --fail-prob 0.1
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def _append_journal(path: str, event: dict) -> None:
    """Durably append one controller event to the jsonl journal (same
    torn-tail-tolerant format as ``repro.core.fleet.RunJournal``)."""
    event = dict(event, time=time.time(), source="controller")
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(event) + "\n")
        f.flush()
        os.fsync(f.fileno())


def _read_heartbeat(path: str) -> dict | None:
    """The worker's latest liveness beacon, or None when absent/torn."""
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def _spawn_worker(
    worker_args: list[str], ckpt_dir: str, heartbeat: str, out: str
) -> subprocess.Popen:
    """Launch one sweep worker attempt (controller-owned plumbing flags
    appended after the user's passthrough arguments)."""
    cmd = [
        sys.executable, "-m", "repro.launch.sweep", *worker_args,
        "--ckpt-dir", ckpt_dir, "--heartbeat-file", heartbeat, "--out", out,
    ]
    return subprocess.Popen(cmd)


def _supervise_once(
    proc: subprocess.Popen,
    heartbeat: str,
    *,
    timeout: float,
    poll: float,
    chaos_left: int,
    chaos_min_chunks: int,
    journal: str,
) -> tuple[int | None, str]:
    """Monitor one worker attempt until it exits or must be killed.

    Returns ``(returncode, reason)`` where reason is "exit" (worker
    terminated on its own), "chaos" (intentional chaos SIGKILL) or
    "hang" (heartbeat went stale past ``timeout``).
    """
    spawned = time.time()
    base_hb = _read_heartbeat(heartbeat)
    base_chunk = base_hb["chunk"] if base_hb else -1
    while True:
        rc = proc.poll()
        if rc is not None:
            return rc, "exit"
        hb = _read_heartbeat(heartbeat)
        now = time.time()
        progressed = (
            hb is not None and hb["chunk"] - base_chunk >= chaos_min_chunks
        )
        if chaos_left > 0 and progressed:
            _append_journal(journal, {
                "kind": "worker_kill", "reason": "chaos",
                "pid": proc.pid, "chunk": hb["chunk"],
            })
            proc.kill()
            return proc.wait(), "chaos"
        # freshness: newest of spawn time (covers jax compile before the
        # first beacon) and the last beacon the worker published
        last_beat = max(spawned, hb["time"] if hb else 0.0)
        if now - last_beat > timeout:
            _append_journal(journal, {
                "kind": "heartbeat_miss", "pid": proc.pid,
                "stale_s": now - last_beat,
                "chunk": hb["chunk"] if hb else None,
            })
            proc.kill()
            return proc.wait(), "hang"
        time.sleep(poll)


def main() -> None:
    """CLI entry point — see the module docstring for the contract."""
    ap = argparse.ArgumentParser(
        allow_abbrev=False,
        description="supervise an unattended sweep worker: heartbeat "
                    "monitoring, SIGKILL on hang, resume from the last "
                    "valid checkpoint, completion-rate gate",
    )
    ap.add_argument("--ckpt-dir", required=True,
                    help="durable run directory (checkpoints, journal, "
                         "heartbeat, result) shared with the worker")
    ap.add_argument("--heartbeat-timeout", type=float, default=120.0,
                    help="seconds without a fresh beacon before the worker "
                         "is declared hung and SIGKILLed")
    ap.add_argument("--poll", type=float, default=0.5,
                    help="heartbeat poll interval in seconds")
    ap.add_argument("--max-worker-restarts", type=int, default=10,
                    help="respawn budget for crashed/hung workers (chaos "
                         "kills are exempt)")
    ap.add_argument("--chaos-kills", type=int, default=0,
                    help="SIGKILL the worker this many times after it has "
                         "made progress — the kill/resume CI smoke")
    ap.add_argument("--chaos-min-chunks", type=int, default=1,
                    help="committed chunks since spawn before a chaos kill "
                         "may fire")
    ap.add_argument("--result-json", default=None,
                    help="worker result JSON path (default: "
                         "<ckpt-dir>/result.json); the completion gate "
                         "reads fault_info from it")
    ap.add_argument("--journal", default=None,
                    help="controller event journal (default: "
                         "<ckpt-dir>/controller.jsonl)")
    argv = sys.argv[1:]
    if "--" in argv:
        split = argv.index("--")
        own, worker_args = argv[:split], argv[split + 1:]
    else:
        own, worker_args = argv, []
    args = ap.parse_args(own)

    os.makedirs(args.ckpt_dir, exist_ok=True)
    heartbeat = os.path.join(args.ckpt_dir, "heartbeat.json")
    result = args.result_json or os.path.join(args.ckpt_dir, "result.json")
    journal = args.journal or os.path.join(args.ckpt_dir, "controller.jsonl")

    chaos_left = args.chaos_kills
    restarts = 0
    attempt = 0
    while True:
        attempt += 1
        proc = _spawn_worker(worker_args, args.ckpt_dir, heartbeat, result)
        _append_journal(journal, {
            "kind": "spawn", "attempt": attempt, "pid": proc.pid,
            "restarts": restarts, "chaos_left": chaos_left,
        })
        rc, reason = _supervise_once(
            proc, heartbeat,
            timeout=args.heartbeat_timeout, poll=args.poll,
            chaos_left=chaos_left, chaos_min_chunks=args.chaos_min_chunks,
            journal=journal,
        )
        _append_journal(journal, {
            "kind": "worker_exit", "attempt": attempt, "returncode": rc,
            "reason": reason,
        })
        if reason == "chaos":
            chaos_left -= 1
            continue
        if rc == 0:
            break
        restarts += 1
        if restarts > args.max_worker_restarts:
            _append_journal(journal, {
                "kind": "giveup", "restarts": restarts,
            })
            print(f"[controller] giving up after {restarts} restarts",
                  file=sys.stderr)
            sys.exit(2)

    try:
        with open(result) as f:
            info = json.load(f)["fault_info"]
    except (OSError, KeyError, json.JSONDecodeError) as e:
        print(f"[controller] worker exited 0 but result JSON is unusable: "
              f"{e}", file=sys.stderr)
        sys.exit(2)
    eligible = info.get("eligible_completion_rate", 0.0)
    _append_journal(journal, {
        "kind": "complete",
        "attempts": attempt,
        "restarts": restarts,
        "chaos_kills": args.chaos_kills - chaos_left,
        "completion_rate": info.get("completion_rate"),
        "eligible_completion_rate": eligible,
        "quarantined": info.get("quarantined", []),
    })
    print(f"[controller] run complete after {attempt} attempt(s): "
          f"completion {info.get('completion_rate', 0.0)*100:.1f}%, "
          f"eligible {eligible*100:.1f}%, "
          f"quarantined {info.get('quarantined', [])}")
    if eligible != 1.0:
        print("[controller] GATE FAILED: eligible completion below 100%",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
