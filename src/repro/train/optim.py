"""AdamW + global-norm clipping + LR schedules, implemented in pure JAX.

First/second moments are kept in a configurable dtype (fp32 default); the
update math runs in fp32 regardless of param dtype (bf16 params at scale).
Includes an int8 error-feedback gradient-compression hook for slow cross-pod
links (DESIGN.md §7) — off by default, exercised in tests.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config.base import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params: Any, dtype: str = "float32") -> AdamWState:
    dt = jnp.dtype(dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def lr_schedule(tc: TrainConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(tc.warmup_steps, 1), 1.0)
    if tc.schedule == "constant":
        decay = 1.0
    elif tc.schedule == "linear":
        frac = jnp.clip(
            (s - tc.warmup_steps)
            / jnp.maximum(tc.total_steps - tc.warmup_steps, 1),
            0.0, 1.0,
        )
        decay = 1.0 - frac
    else:  # cosine
        frac = jnp.clip(
            (s - tc.warmup_steps)
            / jnp.maximum(tc.total_steps - tc.warmup_steps, 1),
            0.0, 1.0,
        )
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return tc.learning_rate * warm * decay


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree)
        )
    )


def clip_by_global_norm(grads: Any, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
    ), norm


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    tc: TrainConfig,
) -> tuple[Any, AdamWState, dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    step = state.step + 1
    lr = lr_schedule(tc, step)
    b1, b2 = tc.beta1, tc.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + tc.eps)
        pf = p.astype(jnp.float32)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            pf = pf * (1.0 - lr * tc.weight_decay)
        pf = pf - lr * delta
        return pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), stats


# --------------------------------------------------------------------------
# optional int8 error-feedback gradient compression (cross-pod links)
# --------------------------------------------------------------------------

def compress_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization → (q, scale)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_grads_with_feedback(
    grads: Any, error: Any
) -> tuple[Any, Any]:
    """int8-compress (grads + carried error); return (decompressed, new_error).

    The decompressed values are what crosses the slow link; the quantization
    residual is fed back into the next step (error feedback keeps the update
    unbiased over time).
    """

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = compress_int8(target)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), target - deq

    pairs = jax.tree.map(one, grads, error)
    new_g = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_e
