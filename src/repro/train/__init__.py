from repro.train.optim import AdamWState, adamw_init, adamw_update, lr_schedule
from repro.train.step import make_train_step, cross_entropy_loss

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "lr_schedule",
    "make_train_step",
    "cross_entropy_loss",
]
