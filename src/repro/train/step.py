"""Train step: loss, gradient accumulation (microbatching), remat, metrics.

``make_train_step`` builds the jit-able (params, opt_state, batch) →
(params, opt_state, metrics) function used by both the trainer loop and the
multi-pod dry-run. Gradient accumulation scans over microbatches so the
activation working set is bounded at any global batch (the big-arch cells).
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig, TrainConfig
from repro.models.registry import Model
from repro.train.optim import AdamWState, adamw_init, adamw_update


def cross_entropy_loss(
    logits: jax.Array,        # [B, S, V] f32 (vocab axis may be tp-sharded)
    labels: jax.Array,        # [B, S] i32
    z_loss: float = 0.0,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    lse = jax.nn.logsumexp(logits, axis=-1)
    # masked-sum instead of take_along_axis: reduces over the (sharded)
    # vocab axis without gathering the full logits to one shard
    hit = jnp.arange(logits.shape[-1])[None, None, :] == labels[..., None]
    picked = jnp.where(hit, logits, 0.0).sum(axis=-1)
    nll = (lse - picked).mean()
    metrics = {"ce": nll}
    if z_loss > 0.0:
        zl = z_loss * jnp.square(lse).mean()
        nll = nll + zl
        metrics["z_loss"] = zl
    return nll, metrics


def make_loss_fn(model: Model, tc: TrainConfig,
                 unroll: bool = False) -> Callable:
    def loss_fn(params, batch):
        logits, aux = model.apply(params, batch, remat=tc.remat,
                                  unroll=unroll)
        loss, metrics = cross_entropy_loss(
            logits, batch["labels"], tc.z_loss
        )
        if model.cfg.n_experts > 0:
            loss = loss + tc.moe_aux_weight * aux
            metrics["moe_aux"] = aux
        metrics["loss"] = loss
        return loss, metrics

    return loss_fn


def _split_microbatches(batch: dict, n: int) -> dict:
    """[B, ...] → [n, B//n, ...] on every batch-led leaf; mrope is [3,B,S]."""

    def one(path_is_mrope, x):
        if path_is_mrope:
            b = x.shape[1]
            return x.reshape(x.shape[0], n, b // n, *x.shape[2:]).swapaxes(0, 1)
        b = x.shape[0]
        return x.reshape(n, b // n, *x.shape[1:])

    return {
        k: one(k == "mrope_pos", v) for k, v in batch.items()
    }


def make_train_step(
    model: Model, tc: TrainConfig, unroll: bool = False
) -> Callable[[Any, AdamWState, dict], tuple[Any, AdamWState, dict]]:
    """``unroll=True`` python-unrolls both the layer stack and the
    microbatch-accumulation loop (dry-run coster; scan trip counts are
    invisible to HLO cost analysis)."""
    loss_fn = make_loss_fn(model, tc, unroll=unroll)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: AdamWState, batch: dict):
        if tc.microbatches > 1:
            mb = _split_microbatches(batch, tc.microbatches)

            def body(carry, mbatch):
                acc, metrics_acc = carry
                (_, metrics), grads = grad_fn(params, mbatch)
                acc = jax.tree.map(jnp.add, acc, grads)
                metrics_acc = jax.tree.map(jnp.add, metrics_acc, metrics)
                return (acc, metrics_acc), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            zero_m = jax.eval_shape(
                lambda p, b: grad_fn(p, b)[0][1], params,
                jax.tree.map(lambda x: x[0], mb),
            )
            zero_m = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), zero_m)
            if unroll:
                carry = (zero_g, zero_m)
                for i in range(tc.microbatches):
                    carry, _ = body(
                        carry, jax.tree.map(lambda x, i=i: x[i], mb)
                    )
                grads, metrics = carry
            else:
                (grads, metrics), _ = jax.lax.scan(
                    body, (zero_g, zero_m), mb
                )
            inv = 1.0 / tc.microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            metrics = jax.tree.map(lambda m: m * inv, metrics)
        else:
            (_, metrics), grads = grad_fn(params, batch)

        params, opt_state, stats = adamw_update(grads, opt_state, params, tc)
        metrics.update(stats)
        return params, opt_state, metrics

    return train_step


def abstract_train_state(model: Model, tc: TrainConfig):
    """(params, opt_state) as ShapeDtypeStructs — dry-run path, no alloc."""
    params = model.abstract_params()
    opt_state = jax.eval_shape(
        functools.partial(adamw_init, dtype=tc.opt_state_dtype), params
    )
    return params, opt_state
