"""Trainer loop: data feed, jitted step, checkpointing, restart.

The loop is deliberately small — all heavy lifting is in ``make_train_step``
(jit) and ``CheckpointManager`` (async I/O). Restart resumes from the latest
checkpoint including the data cursor, so a killed job continues bit-exact
(the training-side mirror of the sweep engine's fault tolerance).
"""

from __future__ import annotations

import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager
from repro.config.base import TrainConfig
from repro.models.registry import Model
from repro.train.optim import adamw_init
from repro.train.step import make_train_step


class Trainer:
    def __init__(
        self,
        model: Model,
        tc: TrainConfig,
        data: Iterator[dict],
        ckpt_dir: str | None = None,
        ckpt_every: int = 50,
        log_every: int = 10,
        log_fn: Callable[[str], None] = print,
    ) -> None:
        self.model = model
        self.tc = tc
        self.data = data
        self.step_fn = jax.jit(make_train_step(model, tc), donate_argnums=(0, 1))
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.log = log_fn
        self.history: list[dict] = []

    def init_state(self):
        params = self.model.init(jax.random.key(self.tc.seed))
        opt_state = adamw_init(params, self.tc.opt_state_dtype)
        return params, opt_state, 0

    def restore_or_init(self):
        params, opt_state, start = self.init_state()
        if self.ckpt is not None and self.ckpt.has_checkpoint():
            (params, opt_state), meta = self.ckpt.restore(
                like=(params, opt_state)
            )
            start = int(meta["step"])
            self.log(f"[trainer] restored checkpoint at step {start}")
        return params, opt_state, start

    def run(self, steps: int | None = None):
        params, opt_state, start = self.restore_or_init()
        total = steps if steps is not None else self.tc.total_steps
        t0 = time.perf_counter()
        for step in range(start, total):
            batch = next(self.data)
            params, opt_state, metrics = self.step_fn(
                params, opt_state, batch
            )
            if (step + 1) % self.log_every == 0 or step + 1 == total:
                m = {
                    k: float(jax.device_get(v))
                    for k, v in metrics.items()
                }
                dt = time.perf_counter() - t0
                m["steps_per_s"] = (step + 1 - start) / dt
                self.history.append({"step": step + 1, **m})
                self.log(
                    f"[trainer] step {step+1}/{total} "
                    f"loss={m['loss']:.4f} ce={m['ce']:.4f} "
                    f"gnorm={m['grad_norm']:.3f} "
                    f"({m['steps_per_s']:.2f} it/s)"
                )
            if self.ckpt is not None and (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, (params, opt_state))
        if self.ckpt is not None:
            self.ckpt.save(total, (params, opt_state))
            self.ckpt.wait()
        return params, opt_state
