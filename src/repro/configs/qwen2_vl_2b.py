"""Qwen2-VL-2B [arXiv:2409.12191; hf:Qwen/Qwen2-VL-2B-Instruct] (backbone).

28L d_model=1536 12H (GQA kv=2, head_dim=128) d_ff=8960 vocab=151936.
M-RoPE with (t,h,w) sections (16,24,24) over the 64 rotary half-dims;
QKV bias. The vision patch frontend is a STUB per the assignment.
"""

from repro.config.base import ModelConfig, register_arch


@register_arch("qwen2-vl-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        family="dense",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151_936,
        qkv_bias=True,
        mrope_sections=(16, 24, 24),
        activation="silu",
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        frontend="vision",
    )
