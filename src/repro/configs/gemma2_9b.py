"""Gemma-2 9B [arXiv:2408.00118; hf:google/gemma-2-9b].

42L d_model=3584 16H (GQA kv=8, head_dim=256) d_ff=14336 vocab=256000.
Alternating local(4096-window)/global attention, attn logit softcap 50,
final logit softcap 30, GeGLU, sandwich (pre+post) RMSNorms, tied embeddings.
"""

from repro.config.base import ModelConfig, register_arch


@register_arch("gemma2-9b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256_000,
        layer_pattern=("local", "global"),
        window=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        activation="gelu",
        post_norms=True,
        tie_embeddings=True,
        emb_scale="sqrt_d",
        rope_theta=10_000.0,
    )
