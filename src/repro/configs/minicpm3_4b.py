"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B].

62L d_model=2560 40H d_ff=6400 vocab=73448, MLA attention
(q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v_head=64).
"""

from repro.config.base import ModelConfig, register_arch


@register_arch("minicpm3-4b")
def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        n_layers=62,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        head_dim=64,
        d_ff=6400,
        vocab_size=73_448,
        use_mla=True,
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
        activation="silu",
        tie_embeddings=True,
        emb_scale="const12",
        rope_theta=10_000.0,
    )
