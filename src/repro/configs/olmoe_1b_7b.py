"""OLMoE-1B-7B [arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924].

16L d_model=2048 16H (kv=16, head_dim=128) vocab=50304,
MoE: 64 experts, top-8, expert d_ff=1024, QK-norm.
"""

from repro.config.base import ModelConfig, register_arch


@register_arch("olmoe-1b-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1024,
        vocab_size=50_304,
        qk_norm=True,
        n_experts=64,
        top_k=8,
        moe_d_ff=1024,
        activation="silu",
        rope_theta=10_000.0,
    )
