"""DeepSeek-V2 236B [arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2].

60L d_model=5120 128H vocab=102400. MLA (kv_lora=512, q_lora=1536,
qk_nope=128, qk_rope=64, v_head=128). MoE: 160 routed experts top-6 +
2 shared, expert d_ff=1536; first layer dense with d_ff=12288.
"""

from repro.config.base import ModelConfig, register_arch


@register_arch("deepseek-v2-236b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=1536,
        vocab_size=102_400,
        use_mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        n_experts=160,
        n_shared_experts=2,
        top_k=6,
        moe_d_ff=1536,
        first_dense_layers=1,
        dense_d_ff=12288,
        activation="silu",
        rope_theta=10_000.0,
    )
