"""RWKV-6 (Finch) 3B [arXiv:2404.05892; hf:RWKV/rwkv-6-world-3b].

32L d_model=2560 (attention-free), d_ff=8960, vocab=65536.
WKV6 recurrence with data-dependent decay, head_dim=64 (40 heads),
token-shift mixing, LayerNorm. Sub-quadratic (O(1) decode state).
"""

from repro.config.base import ModelConfig, register_arch


@register_arch("rwkv6-3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="rwkv",
        n_layers=32,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        head_dim=64,
        d_ff=8960,
        vocab_size=65_536,
        layer_pattern=("rwkv",),
        rwkv_head_dim=64,
        norm_kind="layernorm",
        sub_quadratic=True,
    )
