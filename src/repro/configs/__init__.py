"""Assigned-architecture configs. Importing this package populates the registry."""

from repro.configs import (  # noqa: F401
    gemma2_9b,
    gemma2_2b,
    minicpm3_4b,
    qwen15_05b,
    olmoe_1b_7b,
    deepseek_v2_236b,
    recurrentgemma_2b,
    whisper_large_v3,
    qwen2_vl_2b,
    rwkv6_3b,
)

ALL_ARCHS = [
    "gemma2-9b",
    "minicpm3-4b",
    "gemma2-2b",
    "qwen1.5-0.5b",
    "olmoe-1b-7b",
    "deepseek-v2-236b",
    "recurrentgemma-2b",
    "whisper-large-v3",
    "qwen2-vl-2b",
    "rwkv6-3b",
]
