"""Whisper large-v3 [arXiv:2212.04356; hf:openai/whisper-large-v3] (backbone).

Encoder-decoder, 32+32L, d_model=1280 20H (head_dim=64) d_ff=5120
vocab=51866, GELU, LayerNorm. The conv/mel frontend is a STUB per the
assignment: ``input_specs()`` provides post-conv frame embeddings.
"""

from repro.config.base import ModelConfig, register_arch


@register_arch("whisper-large-v3")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="encdec",
        n_layers=32,
        n_enc_layers=32,
        is_encdec=True,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab_size=51_866,
        activation="gelu",
        norm_kind="layernorm",
        tie_embeddings=True,
        frontend="audio",
        enc_ctx=1500,
        rope_theta=0.0,  # absolute positions, no RoPE
    )
