"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427; hf:google/recurrentgemma-2b].

26L d_model=2560 10H (MQA kv=1, head_dim=256) d_ff=7680 vocab=256000.
Layer pattern: (recurrent, recurrent, local-attention) with a 2048 window;
RG-LRU recurrence width 2560, temporal conv width 4. Sub-quadratic.
"""

from repro.config.base import ModelConfig, register_arch


@register_arch("recurrentgemma-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256_000,
        layer_pattern=("recurrent", "recurrent", "local"),
        window=2048,
        lru_width=2560,
        conv_width=4,
        activation="gelu",
        tie_embeddings=True,
        emb_scale="sqrt_d",
        rope_theta=10_000.0,
        sub_quadratic=True,
    )
