"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B].

24L d_model=1024 16H (MHA, kv=16, head_dim=64) d_ff=2816 vocab=151936.
QKV bias, SwiGLU, tied embeddings, rope_theta=1e6.
"""

from repro.config.base import ModelConfig, register_arch


@register_arch("qwen1.5-0.5b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b",
        family="dense",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=2816,
        vocab_size=151_936,
        qkv_bias=True,
        activation="silu",
        tie_embeddings=True,
        rope_theta=1_000_000.0,
    )
