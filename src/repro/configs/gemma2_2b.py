"""Gemma-2 2B [arXiv:2408.00118; hf:google/gemma-2-2b].

26L d_model=2304 8H (GQA kv=4, head_dim=256) d_ff=9216 vocab=256000.
Same local/global alternation + softcaps as gemma2-9b.
"""

from repro.config.base import ModelConfig, register_arch


@register_arch("gemma2-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        family="dense",
        n_layers=26,
        d_model=2304,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256_000,
        layer_pattern=("local", "global"),
        window=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        activation="gelu",
        post_norms=True,
        tie_embeddings=True,
        emb_scale="sqrt_d",
        rope_theta=10_000.0,
    )
