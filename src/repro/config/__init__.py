from repro.config.base import (
    ModelConfig,
    ShapeConfig,
    TrainConfig,
    ServeConfig,
    MeshConfig,
    SHAPES,
    register_arch,
    get_arch,
    list_archs,
)

__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "TrainConfig",
    "ServeConfig",
    "MeshConfig",
    "SHAPES",
    "register_arch",
    "get_arch",
    "list_archs",
]
