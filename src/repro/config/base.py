"""Config system: frozen dataclasses + an architecture registry.

Every assigned architecture registers a full-size :class:`ModelConfig` in
``repro/configs/<id>.py``; reduced smoke-test variants come from
:meth:`ModelConfig.reduced`, which preserves the family topology (layer
pattern, MoE-ness, MLA-ness, ...) while shrinking every dimension.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable


# --------------------------------------------------------------------------
# Model configuration
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # "dense" | "moe" | "hybrid" | "rwkv" | "encdec"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # ---- per-layer pattern: repeating cycle of layer kinds --------------
    # entries: "global" | "local" | "recurrent" | "rwkv"
    layer_pattern: tuple[str, ...] = ("global",)
    window: int = 0  # sliding-window size for "local" layers

    # ---- attention -------------------------------------------------------
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, ...] = ()  # qwen2-vl M-RoPE (t,h,w) half-dims

    # ---- MLA (deepseek-v2, minicpm3) --------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # ---- FFN / MoE ---------------------------------------------------------
    activation: str = "silu"  # "silu" | "gelu"
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0  # deepseek-v2: leading dense layers
    dense_d_ff: int = 0          # d_ff of those leading dense layers
    capacity_factor: float = 1.25

    # ---- recurrent (RG-LRU) ------------------------------------------------
    lru_width: int = 0
    conv_width: int = 4

    # ---- rwkv --------------------------------------------------------------
    rwkv_head_dim: int = 64

    # ---- encoder-decoder (whisper) ------------------------------------------
    is_encdec: bool = False
    n_enc_layers: int = 0
    enc_ctx: int = 1500  # encoder memory length for decode cells

    # ---- frontends (stubs per assignment) -----------------------------------
    frontend: str = "none"  # "none" | "audio" | "vision"

    # ---- misc ----------------------------------------------------------------
    tie_embeddings: bool = False
    emb_scale: str = "none"  # "none" | "sqrt_d" | "const12"
    norm_eps: float = 1e-6
    post_norms: bool = False  # gemma2 sandwich norms
    norm_kind: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    dtype: str = "bfloat16"
    sub_quadratic: bool = False  # eligible for long_500k decode

    # ------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def pattern_len(self) -> int:
        return len(self.layer_pattern)

    def layer_kind(self, i: int) -> str:
        return self.layer_pattern[i % self.pattern_len]

    def is_moe_layer(self, i: int) -> bool:
        return self.n_experts > 0 and i >= self.first_dense_layers

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: same topology, tiny dimensions."""
        pat = len(self.layer_pattern)
        small = dict(
            n_layers=max(2 * pat, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            window=min(self.window, 16) if self.window else 0,
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=16 if self.kv_lora_rank else 0,
            qk_nope_head_dim=8 if self.use_mla else 0,
            qk_rope_head_dim=8 if self.use_mla else 0,
            v_head_dim=16 if self.use_mla else 0,
            n_experts=min(self.n_experts, 8),
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2),
            moe_d_ff=32 if self.moe_d_ff else 0,
            dense_d_ff=128 if self.dense_d_ff else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            lru_width=64 if self.lru_width else 0,
            rwkv_head_dim=16,
            n_enc_layers=2 if self.is_encdec else 0,
            enc_ctx=8 if self.is_encdec else self.enc_ctx,
            mrope_sections=(2, 3, 3) if self.mrope_sections else (),
        )
        small.update(overrides)
        return dataclasses.replace(self, name=self.name + "-smoke", **small)


# --------------------------------------------------------------------------
# Shapes (assigned per-paper input-shape set)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# --------------------------------------------------------------------------
# Train / serve / mesh configuration
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    schedule: str = "cosine"  # "cosine" | "linear" | "constant"
    microbatches: int = 1     # gradient-accumulation steps per update
    remat: str = "full"       # "none" | "full" | "dots"
    opt_state_dtype: str = "float32"
    z_loss: float = 0.0
    moe_aux_weight: float = 0.01
    seed: int = 0


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 2048
    prefill_chunk: int = 512
    temperature: float = 0.0
    seed: int = 0


@dataclass(frozen=True)
class MeshConfig:
    shape: tuple[int, ...] = (16, 16)
    axes: tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


# --------------------------------------------------------------------------
# Architecture registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register_arch(arch_id: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def get_arch(arch_id: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (populates the registry)

    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[arch_id]()


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)
