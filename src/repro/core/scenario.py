"""Scenario configuration + randomized parameter sampling.

The paper randomizes each simulation instance's traffic demand by re-running
SUMO's ``duarouter`` with a fresh ``$RANDOM`` seed before every run (Appendix
B). Here every instance's demand, driver mix and driver parameters are drawn
from a per-instance PRNG key (``jax.random.fold_in(sweep_key, instance_id)``),
which gives the same property — thousands of runs with meaningful deviations —
with exact reproducibility and no shared mutable state (the TPU-native fix for
the paper's duplicate-TraCI-port bug class).

*Which* simulation runs is no longer baked in here: ``SimConfig.scenario``
names an entry in the scenario registry (:mod:`repro.core.scenarios`), and
``sample_scenario_params`` dispatches to that scenario's ``sample_params``.
The paper's Phase-II workload is the default, ``"highway_merge"``::

      lane 2  ──────────────────────────────────────────▶
      lane 1  ──────────────────────────────────────────▶
      lane 0  ──────────────────────────────────────────▶
      ramp(3) ════════════╗ merge zone ╔═══ (ends; must merge or stop)
                      merge_start   merge_end

See ``repro.core.scenarios`` for the catalog (lane_drop, stop_and_go,
speed_limit_zone, ...) and for how to register a custom scenario.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SimConfig:
    """Static (compile-time) simulator configuration."""

    n_slots: int = 64          # fixed vehicle capacity per instance
    n_lanes: int = 3           # main highway lanes (ramp is lane index n_lanes)
    road_len: float = 1000.0
    # generic scenario-zone extents; the highway merge reads them as the
    # merge zone, lane_drop as the bottleneck taper, speed_limit_zone as
    # the work zone (see each scenario's geometry())
    merge_start: float = 600.0
    merge_end: float = 750.0
    scenario: str = "highway_merge"  # registry name (repro.core.scenarios)
    dt: float = 0.1            # SUMO default step length
    vehicle_len: float = 4.5
    spawn_gap: float = 15.0    # min headway at the spawn point
    # IDM bounds
    b_safe: float = 4.0        # MOBIL safety decel limit
    b_max: float = 8.0         # emergency decel clamp
    mobil_athr: float = 0.1    # MOBIL incentive threshold
    lane_change_cooldown: int = 20  # steps between lane changes
    # merge gap acceptance
    merge_gap_front: float = 8.0
    merge_gap_rear: float = 10.0
    record_every: int = 0      # 0 = no trajectory recording
    # neighborhood engine implementation (repro.core.neighbors):
    # "reference" (per-query O(N²) scans, the parity oracle), "dense"
    # (fused single-pass O(N²)), "sort" (O(N log N) argsort+searchsorted;
    # fastest at every measured n_slots on CPU hosts), "pallas" (the
    # multi-query TPU kernel; interpret mode off-TPU). All four are
    # bit-for-bit equivalent (tests/test_neighbors.py).
    neighbor_impl: str = "sort"


class ScenarioParams(NamedTuple):
    """Per-instance randomized demand + driver-population parameters.

    Every field is a scalar (or per-lane vector) jnp array so a batch of
    instances is just a vmapped axis. The structure is shared by *all*
    registered scenarios (a ``lax.switch`` over scenario step functions needs
    one common pytree): fields a scenario does not use are sampled as zeros,
    and ``aux0``/``aux1`` are generic scenario knobs (speed-limit value,
    perturbation strength, ... — see each scenario's ``sample_params``).
    """

    lambda_main: jax.Array   # [n_lanes] arrival rate veh/s per main lane
    lambda_ramp: jax.Array   # [] arrival rate on the ramp (ramp scenarios)
    p_cav: jax.Array         # [] CAV penetration (paper: mixed traffic)
    v0_mean: jax.Array       # [] mean desired speed
    v0_ramp: jax.Array       # [] desired speed on ramp
    seed: jax.Array          # [] uint32 instance seed (for in-sim draws)
    aux0: jax.Array = 0.0    # [] scenario-specific knob (see scenario doc)
    aux1: jax.Array = 0.0    # [] scenario-specific knob


def sample_scenario_params(key: jax.Array, cfg: SimConfig) -> ScenarioParams:
    """Draw one instance's parameters for ``cfg.scenario`` (registry dispatch)."""
    from repro.core.scenarios import get_scenario  # deferred: avoids cycle

    return get_scenario(cfg.scenario).sample_params(key, cfg)


# Driver-type parameter tables (human, CAV). CAVs run tighter headways and
# react harder — the standard mixed-traffic assumption in the CAV-merge
# literature the paper's Phase II targets.
HUMAN = dict(T=1.5, a_max=1.4, b_comf=2.0, s0=2.0, politeness=0.3)
CAV = dict(T=0.9, a_max=2.0, b_comf=2.5, s0=1.5, politeness=0.5)


def driver_params(is_cav: jax.Array, jitter_key: jax.Array, n: int):
    """Per-vehicle IDM/MOBIL parameters given the CAV mask, with human jitter."""
    jt = jax.random.uniform(jitter_key, (n,), minval=0.85, maxval=1.15)

    def mix(h: float, c: float) -> jax.Array:
        base = jnp.where(is_cav, c, h)
        # humans get parameter jitter, CAVs are standardized
        return jnp.where(is_cav, base, base * jt)

    return dict(
        T=mix(HUMAN["T"], CAV["T"]),
        a_max=mix(HUMAN["a_max"], CAV["a_max"]),
        b_comf=mix(HUMAN["b_comf"], CAV["b_comf"]),
        s0=mix(HUMAN["s0"], CAV["s0"]),
        politeness=jnp.where(is_cav, CAV["politeness"], HUMAN["politeness"]),
    )
