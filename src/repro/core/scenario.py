"""Scenario generation — the ``duarouter --randomize-flows --seed $RANDOM`` analogue.

The paper randomizes each simulation instance's traffic demand by re-running
SUMO's ``duarouter`` with a fresh ``$RANDOM`` seed before every run (Appendix
B). Here every instance's demand, driver mix and driver parameters are drawn
from a per-instance PRNG key (``jax.random.fold_in(sweep_key, instance_id)``),
which gives the same property — thousands of runs with meaningful deviations —
with exact reproducibility and no shared mutable state (the TPU-native fix for
the paper's duplicate-TraCI-port bug class).

Scenario: the paper's Phase-II workload, a mixed-traffic highway merge.
Geometry (all distances in meters, speeds in m/s)::

      lane 2  ──────────────────────────────────────────▶
      lane 1  ──────────────────────────────────────────▶
      lane 0  ──────────────────────────────────────────▶
      ramp(3) ════════════╗ merge zone ╔═══ (ends; must merge or stop)
                      merge_start   merge_end
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SimConfig:
    """Static (compile-time) simulator configuration."""

    n_slots: int = 64          # fixed vehicle capacity per instance
    n_lanes: int = 3           # main highway lanes (ramp is lane index n_lanes)
    road_len: float = 1000.0
    merge_start: float = 600.0
    merge_end: float = 750.0
    dt: float = 0.1            # SUMO default step length
    vehicle_len: float = 4.5
    spawn_gap: float = 15.0    # min headway at the spawn point
    # IDM bounds
    b_safe: float = 4.0        # MOBIL safety decel limit
    b_max: float = 8.0         # emergency decel clamp
    mobil_athr: float = 0.1    # MOBIL incentive threshold
    lane_change_cooldown: int = 20  # steps between lane changes
    # merge gap acceptance
    merge_gap_front: float = 8.0
    merge_gap_rear: float = 10.0
    record_every: int = 0      # 0 = no trajectory recording
    # neighborhood engine implementation (repro.core.neighbors):
    # "reference" (per-query O(N²) scans, the parity oracle), "dense"
    # (fused single-pass O(N²)), "sort" (O(N log N) argsort+searchsorted;
    # fastest at every measured n_slots on CPU hosts), "pallas" (the
    # multi-query TPU kernel; interpret mode off-TPU). All four are
    # bit-for-bit equivalent (tests/test_neighbors.py).
    neighbor_impl: str = "sort"


class ScenarioParams(NamedTuple):
    """Per-instance randomized demand + driver-population parameters.

    Every field is a scalar (or per-lane vector) jnp array so a batch of
    instances is just a vmapped axis.
    """

    lambda_main: jax.Array   # [n_lanes] arrival rate veh/s per main lane
    lambda_ramp: jax.Array   # [] arrival rate on the ramp
    p_cav: jax.Array         # [] CAV penetration (paper: mixed traffic)
    v0_mean: jax.Array       # [] mean desired speed
    v0_ramp: jax.Array       # [] desired speed on ramp
    seed: jax.Array          # [] uint32 instance seed (for in-sim draws)


def sample_scenario_params(key: jax.Array, cfg: SimConfig) -> ScenarioParams:
    """Draw one instance's scenario. Ranges follow typical highway calibration."""
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    lambda_main = jax.random.uniform(
        k1, (cfg.n_lanes,), minval=0.15, maxval=0.55
    )
    lambda_ramp = jax.random.uniform(k2, (), minval=0.05, maxval=0.30)
    p_cav = jax.random.uniform(k3, (), minval=0.0, maxval=1.0)
    v0_mean = jax.random.uniform(k4, (), minval=26.0, maxval=33.0)
    v0_ramp = v0_mean * 0.7
    seed = jax.random.randint(k5, (), 0, 2**31 - 1).astype(jnp.uint32)
    return ScenarioParams(lambda_main, lambda_ramp, p_cav, v0_mean, v0_ramp, seed)


# Driver-type parameter tables (human, CAV). CAVs run tighter headways and
# react harder — the standard mixed-traffic assumption in the CAV-merge
# literature the paper's Phase II targets.
HUMAN = dict(T=1.5, a_max=1.4, b_comf=2.0, s0=2.0, politeness=0.3)
CAV = dict(T=0.9, a_max=2.0, b_comf=2.5, s0=1.5, politeness=0.5)


def driver_params(is_cav: jax.Array, jitter_key: jax.Array, n: int):
    """Per-vehicle IDM/MOBIL parameters given the CAV mask, with human jitter."""
    jt = jax.random.uniform(jitter_key, (n,), minval=0.85, maxval=1.15)

    def mix(h: float, c: float) -> jax.Array:
        base = jnp.where(is_cav, c, h)
        # humans get parameter jitter, CAVs are standardized
        return jnp.where(is_cav, base, base * jt)

    return dict(
        T=mix(HUMAN["T"], CAV["T"]),
        a_max=mix(HUMAN["a_max"], CAV["a_max"]),
        b_comf=mix(HUMAN["b_comf"], CAV["b_comf"]),
        s0=mix(HUMAN["s0"], CAV["s0"]),
        politeness=jnp.where(is_cav, CAV["politeness"], HUMAN["politeness"]),
    )
