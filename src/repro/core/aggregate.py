"""Big-data output aggregation (paper §2.10).

The paper's pipeline exists to aggregate thousands of per-run output datasets
into one large dataset for ML (Phase III). Here a finished sweep's stacked
:class:`SimMetrics` *is* that dataset; this module turns it into per-instance
records and population summaries (the quantities the Phase-III models learn
to predict: throughput, merge success, safety).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np

from repro.core.simulator import SimMetrics
from repro.core.scenario import ScenarioParams


def metrics_to_records(
    metrics: SimMetrics, params: ScenarioParams | None = None
) -> list[dict[str, Any]]:
    """Stacked [N] metrics → list of per-instance dict records."""
    m = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), metrics)
    n = m.throughput.shape[0]
    p = (
        jax.tree.map(lambda x: np.asarray(jax.device_get(x)), params)
        if params is not None
        else None
    )
    records = []
    for i in range(n):
        rec = {
            "instance": i,
            "throughput": int(m.throughput[i]),
            "spawned": int(m.spawned[i]),
            "mean_speed": float(
                m.speed_sum[i] / max(float(m.speed_count[i]), 1.0)
            ),
            "collisions": int(m.collisions[i]),
            "merges_ok": int(m.merges_ok[i]),
            "ramp_blocked_steps": int(m.ramp_blocked_steps[i]),
            "lane_changes": int(m.lane_changes[i]),
            "min_ttc": float(m.min_ttc[i]),
            "steps": int(m.steps[i]),
        }
        if p is not None:
            rec.update(
                lambda_main=[float(x) for x in np.atleast_1d(p.lambda_main[i])],
                lambda_ramp=float(p.lambda_ramp[i]),
                p_cav=float(p.p_cav[i]),
                v0_mean=float(p.v0_mean[i]),
            )
        records.append(rec)
    return records


def aggregate_metrics(metrics: SimMetrics) -> dict[str, float]:
    """Population summary over a sweep — the 'massive output dataset' digest."""
    m = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), metrics)
    speed = m.speed_sum / np.maximum(m.speed_count, 1.0)
    total_steps = float(m.steps.sum())
    return {
        "instances": int(m.throughput.shape[0]),
        "total_throughput": int(m.throughput.sum()),
        "total_spawned": int(m.spawned.sum()),
        "mean_speed": float(speed.mean()),
        "p10_speed": float(np.percentile(speed, 10)),
        "p90_speed": float(np.percentile(speed, 90)),
        "total_collisions": int(m.collisions.sum()),
        "collision_rate_per_kstep": float(
            1000.0 * m.collisions.sum() / max(total_steps, 1.0)
        ),
        "total_merges": int(m.merges_ok.sum()),
        "min_ttc": float(m.min_ttc.min()),
        "total_sim_steps": int(total_steps),
    }
