"""Big-data output aggregation (paper §2.10).

The paper's pipeline exists to aggregate thousands of per-run output datasets
into one large dataset for ML (Phase III). Here a finished sweep's stacked
:class:`SimMetrics` *is* that dataset; this module turns it into per-instance
records and population summaries (the quantities the Phase-III models learn
to predict: throughput, merge success, safety).

Scenario awareness: pass the sweep's ``scenario_id`` vector and roster
(``SweepConfig.scenarios``) and records gain a ``scenario`` field plus the
scenario's *aliased* metric names (``Scenario.metric_aliases`` — e.g. the
``ramp_blocked_steps`` slot surfaces as ``stopped_steps`` for a ring road),
while summaries gain a ``per_scenario`` group-by.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import numpy as np

from repro.core.simulator import SimMetrics
from repro.core.scenario import ScenarioParams
from repro.core.scenarios import get_scenario


def _scenario_of(i: int, scenario_ids, scenario_names) -> str | None:
    if scenario_ids is None or scenario_names is None:
        return None
    return scenario_names[int(scenario_ids[i])]


def metrics_to_records(
    metrics: SimMetrics,
    params: ScenarioParams | None = None,
    scenario_ids: Any = None,
    scenario_names: Sequence[str] | None = None,
) -> list[dict[str, Any]]:
    """Stacked [N] metrics → list of per-instance dict records."""
    m = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), metrics)
    n = m.throughput.shape[0]
    p = (
        jax.tree.map(lambda x: np.asarray(jax.device_get(x)), params)
        if params is not None
        else None
    )
    if scenario_ids is not None:
        scenario_ids = np.asarray(jax.device_get(scenario_ids))
    records = []
    for i in range(n):
        rec = {
            "instance": i,
            "throughput": int(m.throughput[i]),
            "spawned": int(m.spawned[i]),
            "mean_speed": float(
                m.speed_sum[i] / max(float(m.speed_count[i]), 1.0)
            ),
            "collisions": int(m.collisions[i]),
            "merges_ok": int(m.merges_ok[i]),
            "ramp_blocked_steps": int(m.ramp_blocked_steps[i]),
            "lane_changes": int(m.lane_changes[i]),
            "min_ttc": float(m.min_ttc[i]),
            "steps": int(m.steps[i]),
        }
        name = _scenario_of(i, scenario_ids, scenario_names)
        if name is not None:
            rec["scenario"] = name
            # surface the scenario's meaning of the generic metric slots
            for generic, alias in get_scenario(name).metric_aliases.items():
                rec[alias] = rec[generic]
        if p is not None:
            rec.update(
                lambda_main=[float(x) for x in np.atleast_1d(p.lambda_main[i])],
                lambda_ramp=float(p.lambda_ramp[i]),
                p_cav=float(p.p_cav[i]),
                v0_mean=float(p.v0_mean[i]),
                aux0=float(np.atleast_1d(p.aux0)[i])
                if np.ndim(p.aux0) else float(p.aux0),
                aux1=float(np.atleast_1d(p.aux1)[i])
                if np.ndim(p.aux1) else float(p.aux1),
            )
        records.append(rec)
    return records


def _summarize(m: SimMetrics, sel: np.ndarray) -> dict[str, float]:
    speed = m.speed_sum[sel] / np.maximum(m.speed_count[sel], 1.0)
    total_steps = float(m.steps[sel].sum())
    return {
        "instances": int(sel.sum()),
        "total_throughput": int(m.throughput[sel].sum()),
        "total_spawned": int(m.spawned[sel].sum()),
        "mean_speed": float(speed.mean()),
        "p10_speed": float(np.percentile(speed, 10)),
        "p90_speed": float(np.percentile(speed, 90)),
        "total_collisions": int(m.collisions[sel].sum()),
        "collision_rate_per_kstep": float(
            1000.0 * m.collisions[sel].sum() / max(total_steps, 1.0)
        ),
        "total_merges": int(m.merges_ok[sel].sum()),
        "total_ramp_blocked_steps": int(m.ramp_blocked_steps[sel].sum()),
        "min_ttc": float(m.min_ttc[sel].min()),
        "total_sim_steps": int(total_steps),
    }


def aggregate_metrics(
    metrics: SimMetrics,
    scenario_ids: Any = None,
    scenario_names: Sequence[str] | None = None,
) -> dict[str, Any]:
    """Population summary over a sweep — the 'massive output dataset' digest.

    With ``scenario_ids``/``scenario_names`` the summary also carries a
    ``per_scenario`` dict: the same digest grouped by workload (a mixed
    sweep's per-scenario completion/throughput/safety table).
    """
    m = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), metrics)
    all_sel = np.ones(m.throughput.shape[0], bool)
    out: dict[str, Any] = _summarize(m, all_sel)
    if scenario_ids is not None and scenario_names is not None:
        ids = np.asarray(jax.device_get(scenario_ids))
        per: dict[str, Any] = {}
        # group by NAME, not roster slot: a weighted mix may list the same
        # scenario several times (e.g. stop_and_go,stop_and_go,highway_merge)
        for name in dict.fromkeys(scenario_names):  # unique, order-stable
            slots = [s for s, n in enumerate(scenario_names) if n == name]
            sel = np.isin(ids, slots)
            if not sel.any():
                continue
            sub = _summarize(m, sel)
            # rename the generic slots to what they mean for this workload
            for generic, alias in get_scenario(name).metric_aliases.items():
                total_key = {
                    "merges_ok": "total_merges",
                    "throughput": "total_throughput",
                    "spawned": "total_spawned",
                    "collisions": "total_collisions",
                    "ramp_blocked_steps": "total_ramp_blocked_steps",
                }.get(generic)
                if total_key and total_key in sub:
                    sub[f"total_{alias}"] = sub.pop(total_key)
            per[name] = sub
        out["per_scenario"] = per
    return out
