"""Big-data output aggregation (paper §2.10).

The paper's pipeline exists to aggregate thousands of per-run output datasets
into one large dataset for ML (Phase III). Here a finished sweep's stacked
:class:`SimMetrics` *is* that dataset; this module turns it into per-instance
records and population summaries (the quantities the Phase-III models learn
to predict: throughput, merge success, safety).

Scenario awareness: pass the sweep's ``scenario_id`` vector and roster
(``SweepConfig.scenarios``) and records gain a ``scenario`` field plus the
scenario's *aliased* metric names (``Scenario.metric_aliases`` — e.g. the
``ramp_blocked_steps`` slot surfaces as ``stopped_steps`` for a ring road),
while summaries gain a ``per_scenario`` group-by.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import numpy as np

from repro.core.simulator import SimMetrics
from repro.core.scenario import ScenarioParams
from repro.core.scenarios import get_scenario


# columnar layout shared by metrics_to_columns / metrics_to_records and the
# shard writer — ordered exactly as records have always been keyed
_METRIC_COLUMNS = (
    "throughput", "spawned", "mean_speed", "collisions", "merges_ok",
    "ramp_blocked_steps", "lane_changes", "min_ttc", "steps",
)
_PARAM_COLUMNS = (
    "lambda_main", "lambda_ramp", "p_cav", "v0_mean", "aux0", "aux1",
)


def _bcast(x: np.ndarray, n: int) -> np.ndarray:
    """Per-instance column even when a param leaf was sampled as a scalar."""
    x = np.asarray(x)
    return np.broadcast_to(x, (n,) + x.shape[1:]) if x.ndim else np.full(n, x)


def metrics_to_columns(
    metrics: SimMetrics,
    params: ScenarioParams | None = None,
    scenario_ids: Any = None,
    scenario_names: Sequence[str] | None = None,
) -> dict[str, np.ndarray]:
    """Stacked [N] metrics → columnar numpy dataset (fully vectorized).

    This is the dataset-writer's native layout (one array per field, no
    per-instance Python) and the engine under :func:`metrics_to_records`.
    Integer columns come out i64, float columns f32/f64; ``lambda_main`` is
    the one 2-D column ([N, n_lanes]).
    """
    m = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), metrics)
    n = m.throughput.shape[0]
    cols: dict[str, np.ndarray] = {"instance": np.arange(n, dtype=np.int64)}
    cols["throughput"] = m.throughput.astype(np.int64)
    cols["spawned"] = m.spawned.astype(np.int64)
    cols["mean_speed"] = (
        m.speed_sum / np.maximum(m.speed_count, 1.0)
    ).astype(np.float64)
    cols["collisions"] = m.collisions.astype(np.int64)
    cols["merges_ok"] = m.merges_ok.astype(np.int64)
    cols["ramp_blocked_steps"] = m.ramp_blocked_steps.astype(np.int64)
    cols["lane_changes"] = m.lane_changes.astype(np.int64)
    cols["min_ttc"] = m.min_ttc.astype(np.float64)
    cols["steps"] = m.steps.astype(np.int64)
    if scenario_ids is not None and scenario_names is not None:
        ids = np.asarray(jax.device_get(scenario_ids)).astype(np.int64)
        cols["scenario_id"] = ids
        cols["scenario"] = np.asarray(scenario_names, dtype=object)[ids]
    if params is not None:
        p = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), params)
        cols["lambda_main"] = _bcast(p.lambda_main, n).astype(np.float64)
        for name in ("lambda_ramp", "p_cav", "v0_mean", "aux0", "aux1"):
            cols[name] = _bcast(getattr(p, name), n).astype(np.float64)
    return cols


def metrics_to_records(
    metrics: SimMetrics,
    params: ScenarioParams | None = None,
    scenario_ids: Any = None,
    scenario_names: Sequence[str] | None = None,
) -> list[dict[str, Any]]:
    """Stacked [N] metrics → list of per-instance dict records.

    Built on :func:`metrics_to_columns`: every numeric conversion happens
    as one bulk ``.tolist()`` per column instead of the historical
    per-instance ``int()``/``float()`` calls (which dominated at N≥10k).
    The dict-per-instance output shape and key order are unchanged.
    """
    return records_from_columns(
        metrics_to_columns(metrics, params, scenario_ids, scenario_names)
    )


def records_from_columns(cols: dict[str, np.ndarray]) -> list[dict[str, Any]]:
    """:func:`metrics_to_columns` output → per-instance dict records (for
    callers that already built the columns, e.g. the shard writer)."""
    n = cols["instance"].shape[0]
    has_scenario = "scenario" in cols
    has_params = "lambda_main" in cols
    # bulk-convert to Python scalars/lists once per column
    base_keys = ("instance",) + _METRIC_COLUMNS
    lists = {k: cols[k].tolist() for k in base_keys}
    if has_scenario:
        names = cols["scenario"].tolist()
        aliases = {
            name: get_scenario(name).metric_aliases
            for name in dict.fromkeys(names)
        }
    if has_params:
        lists.update({k: cols[k].tolist() for k in _PARAM_COLUMNS})
    records: list[dict[str, Any]] = []
    for i in range(n):
        rec = {k: lists[k][i] for k in base_keys}
        if has_scenario:
            rec["scenario"] = names[i]
            # surface the scenario's meaning of the generic metric slots
            for generic, alias in aliases[names[i]].items():
                rec[alias] = rec[generic]
        if has_params:
            for k in _PARAM_COLUMNS:
                rec[k] = lists[k][i]
        records.append(rec)
    return records


def _summarize(m: SimMetrics, sel: np.ndarray) -> dict[str, float]:
    speed = m.speed_sum[sel] / np.maximum(m.speed_count[sel], 1.0)
    total_steps = float(m.steps[sel].sum())
    return {
        "instances": int(sel.sum()),
        "total_throughput": int(m.throughput[sel].sum()),
        "total_spawned": int(m.spawned[sel].sum()),
        "mean_speed": float(speed.mean()),
        "p10_speed": float(np.percentile(speed, 10)),
        "p90_speed": float(np.percentile(speed, 90)),
        "total_collisions": int(m.collisions[sel].sum()),
        "collision_rate_per_kstep": float(
            1000.0 * m.collisions[sel].sum() / max(total_steps, 1.0)
        ),
        "total_merges": int(m.merges_ok[sel].sum()),
        "total_ramp_blocked_steps": int(m.ramp_blocked_steps[sel].sum()),
        "min_ttc": float(m.min_ttc[sel].min()),
        "total_sim_steps": int(total_steps),
    }


def aggregate_metrics(
    metrics: SimMetrics,
    scenario_ids: Any = None,
    scenario_names: Sequence[str] | None = None,
) -> dict[str, Any]:
    """Population summary over a sweep — the 'massive output dataset' digest.

    With ``scenario_ids``/``scenario_names`` the summary also carries a
    ``per_scenario`` dict: the same digest grouped by workload (a mixed
    sweep's per-scenario completion/throughput/safety table).
    """
    m = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), metrics)
    all_sel = np.ones(m.throughput.shape[0], bool)
    out: dict[str, Any] = _summarize(m, all_sel)
    if scenario_ids is not None and scenario_names is not None:
        ids = np.asarray(jax.device_get(scenario_ids))
        per: dict[str, Any] = {}
        # group by NAME, not roster slot: a weighted mix may list the same
        # scenario several times (e.g. stop_and_go,stop_and_go,highway_merge)
        for name in dict.fromkeys(scenario_names):  # unique, order-stable
            slots = [s for s, n in enumerate(scenario_names) if n == name]
            sel = np.isin(ids, slots)
            if not sel.any():
                continue
            sub = _summarize(m, sel)
            # rename the generic slots to what they mean for this workload
            for generic, alias in get_scenario(name).metric_aliases.items():
                total_key = {
                    "merges_ok": "total_merges",
                    "throughput": "total_throughput",
                    "spawned": "total_spawned",
                    "collisions": "total_collisions",
                    "ramp_blocked_steps": "total_ramp_blocked_steps",
                }.get(generic)
                if total_key and total_key in sub:
                    sub[f"total_{alias}"] = sub.pop(total_key)
            per[name] = sub
        out["per_scenario"] = per
    return out
