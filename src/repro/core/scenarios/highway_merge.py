"""The paper's Phase-II workload: a mixed-traffic highway on-ramp merge.

Geometry (all distances in meters, speeds in m/s)::

      lane 2  ──────────────────────────────────────────▶
      lane 1  ──────────────────────────────────────────▶
      lane 0  ──────────────────────────────────────────▶
      ramp(3) ════════════╗ merge zone ╔═══ (ends; must merge or stop)
                      merge_start   merge_end

This module is the seed simulator's hardcoded behavior extracted verbatim
into the Scenario API — bit-for-bit trajectory parity with the pre-refactor
``sim_step`` is asserted by ``tests/test_scenarios.py``:

- ramp vehicles brake against a virtual standing wall at the ramp end
  (``longitudinal_mods``) and are excluded from MOBIL (``mobil_eligible``);
- inside the merge zone they take a gap-acceptance merge into lane 0, with
  CAVs accepting 0.7× gaps — cooperative merging (``lateral_rules``);
- the ramp is a hard dead end: position clamps at ``merge_end`` with speed
  zeroed (``boundary_clamp``); the gauge counts ramp vehicles stuck there.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scenario import ScenarioParams, SimConfig
from repro.core.scenarios.base import (
    RoadGeometry,
    Scenario,
    end_wall_clamp,
    end_wall_gauge,
    end_wall_mods,
    gap_acceptance,
)


class HighwayMerge(Scenario):
    name = "highway_merge"
    # the generic metric names ARE the merge-flavored ones (seed heritage)
    metric_aliases: dict[str, str] = {}

    def geometry(self, cfg: SimConfig) -> RoadGeometry:
        return RoadGeometry(
            n_lanes=cfg.n_lanes,
            road_len=cfg.road_len,
            special_lane="ramp",
            zone_start=cfg.merge_start,
            zone_end=cfg.merge_end,
        )

    def sample_params(self, key: jax.Array, cfg: SimConfig) -> ScenarioParams:
        """Ranges follow typical highway calibration (seed draw order kept
        exactly — the per-instance PRNG stream is part of the parity
        contract)."""
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        lambda_main = jax.random.uniform(
            k1, (cfg.n_lanes,), minval=0.15, maxval=0.55
        )
        lambda_ramp = jax.random.uniform(k2, (), minval=0.05, maxval=0.30)
        p_cav = jax.random.uniform(k3, (), minval=0.0, maxval=1.0)
        v0_mean = jax.random.uniform(k4, (), minval=26.0, maxval=33.0)
        v0_ramp = v0_mean * 0.7
        seed = jax.random.randint(k5, (), 0, 2**31 - 1).astype(jnp.uint32)
        z = jnp.zeros(())
        return ScenarioParams(
            lambda_main, lambda_ramp, p_cav, v0_mean, v0_ramp, seed, z, z
        )

    # ---------------- longitudinal: ramp-end virtual wall ----------------

    def longitudinal_mods(self, st, cfg, geom, sp, query_lane, nb, a,
                          ctx=None):
        return end_wall_mods(st, geom.zone_end, query_lane == geom.n_lanes, a)

    # ---------------- lateral: gap-acceptance merge ----------------

    def lateral_rules(self, st, cfg, geom, sp, tabs, mobil_lane):
        """Merge from the ramp into lane 0 inside the merge zone."""
        on_ramp = (st.lane == geom.n_lanes) & st.active
        in_zone = (st.pos >= geom.zone_start) & (st.pos <= geom.zone_end)
        gap_ok = gap_acceptance(st, cfg, tabs, jnp.zeros_like(st.lane))
        merge = on_ramp & in_zone & gap_ok
        merged_lane = jnp.where(merge, 0, mobil_lane)
        return merged_lane, jnp.sum(merge.astype(jnp.int32))

    # ---------------- boundary: ramp demand, dead end, blockage ----------

    def boundary_spawn(self, cfg, geom, sp):
        lanes = jnp.arange(geom.n_lanes + 1)
        lam = jnp.concatenate([sp.lambda_main, sp.lambda_ramp[None]])
        base_v0 = jnp.where(lanes == geom.n_lanes, sp.v0_ramp, sp.v0_mean)
        return lam, base_v0, lanes

    def boundary_clamp(self, st, cfg, geom, pos, vel):
        # ramp hard end: cannot drive past it without merging
        return end_wall_clamp(geom.zone_end, st.lane == geom.n_lanes, pos, vel)

    def boundary_gauge(self, st, cfg, geom):
        # vehicle-steps stopped at the ramp end (merge starvation gauge)
        return end_wall_gauge(st, geom.zone_end, st.lane == geom.n_lanes)
