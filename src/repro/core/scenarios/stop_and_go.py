"""Stop-and-go waves: a closed ring road with a periodic braking perturbation.

The canonical traffic-flow instability workload (Sugiyama's circular-track
experiment): vehicles on a ring (positions wrap mod ``road_len``), a braking
perturbation applied periodically in a fixed road band, and the phantom
traffic jams that nucleate from it measured as stopped vehicle-steps.

Hook usage — this scenario exercises the hooks the merge never touches:

- ``longitudinal_mods`` — (a) wrap-around car following: the frontmost
  vehicle of each lane follows that lane's *rearmost* vehicle across the
  seam (the linear neighbor engine reports it lead-less); (b) every
  ``aux1`` seconds, vehicles inside the perturbation band are forced to
  brake at ``aux0`` m/s² for a few seconds — the wave seed.
- ``boundary`` — positions wrap (``boundary_clamp``); there are *no exits*
  (``boundary_exit`` is never), so spawning is self-limiting: arrivals stop
  once the seam headway drops below ``spawn_gap``; the gauge counts stopped
  vehicles (the shockwave-extent metric, → ``stopped_steps``).
- ``lateral_rules`` — pure MOBIL (defaults); multi-lane rings develop
  lane-asymmetric waves.

The collision/TTC stage in the scenario-agnostic ``sim_step`` measures gaps
with a centered wrap (``geom.ring``) so a leader crossing the seam is not a
phantom collision.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scenario import ScenarioParams, SimConfig
from repro.core.scenarios.base import (
    INF,
    RoadGeometry,
    Scenario,
    idm_accel,
)

PERTURB_SECONDS = 5.0       # how long each braking pulse lasts
BAND = (0.45, 0.55)         # perturbation band, as fractions of road_len
SEAM_FRAC = 0.10            # no discretionary lane changes this close to
#                             the seam (linear tables can't see across it)


class StopAndGo(Scenario):
    name = "stop_and_go"
    metric_aliases = {
        "ramp_blocked_steps": "stopped_steps",
        "throughput": "exited",  # structurally present, always 0 on a ring
    }

    def geometry(self, cfg: SimConfig) -> RoadGeometry:
        # a ring long enough to hold the slot capacity at ~30 m/lane spacing
        # (the congested regime where waves nucleate — Sugiyama's setup),
        # never longer than the configured road
        ring_len = min(cfg.road_len, max(cfg.n_slots, 8) * 30.0 / cfg.n_lanes)
        return RoadGeometry(
            n_lanes=cfg.n_lanes,
            road_len=ring_len,
            ring=True,
        )

    def sample_params(self, key: jax.Array, cfg: SimConfig) -> ScenarioParams:
        k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
        z = jnp.zeros(())
        lambda_main = jax.random.uniform(
            k1, (cfg.n_lanes,), minval=0.25, maxval=0.70
        )
        p_cav = jax.random.uniform(k2, (), minval=0.0, maxval=1.0)
        v0_mean = jax.random.uniform(k3, (), minval=26.0, maxval=33.0)
        seed = jax.random.randint(k4, (), 0, 2**31 - 1).astype(jnp.uint32)
        brake = jax.random.uniform(k5, (), minval=2.0, maxval=5.0)   # aux0
        period = jax.random.uniform(k6, (), minval=20.0, maxval=45.0)  # aux1
        return ScenarioParams(
            lambda_main=lambda_main, lambda_ramp=z, p_cav=p_cav,
            v0_mean=v0_mean, v0_ramp=v0_mean, seed=seed,
            aux0=brake, aux1=period,
        )

    # ------------- lateral: MOBIL, but not across/near the seam -----------

    def mobil_eligible(self, st, cfg, geom):
        # the neighbor tables are linear: a lane change just past the seam
        # is invisible to the safety check of a follower still approaching
        # it — forbid discretionary changes in the seam window
        away_from_seam = (
            (st.pos > SEAM_FRAC * geom.road_len)
            & (st.pos < (1.0 - SEAM_FRAC) * geom.road_len)
        )
        return (st.lane < geom.n_lanes) & away_from_seam

    # ------------- longitudinal: wrap leader + periodic perturbation ------

    def snapshot_ctx(self, st, cfg, geom):
        # per-lane rearmost vehicle — the wrap leader across the seam.
        # Computed once per neighborhood snapshot; every accel query on the
        # snapshot (own lane + both MOBIL candidates) reuses it.
        lanes = jnp.arange(geom.n_lanes)
        in_lane = st.active[None, :] & (st.lane[None, :] == lanes[:, None])
        keyed = jnp.where(in_lane, st.pos[None, :], INF)       # [L, N]
        rear_slot = jnp.argmin(keyed, axis=1)                  # [L]
        rear_pos = jnp.min(keyed, axis=1)
        rear_vel = st.vel[rear_slot]
        return rear_pos, rear_vel

    def longitudinal_mods(self, st, cfg, geom, sp, query_lane, nb, a,
                          ctx=None):
        # (a) wrap-around following: lead-less vehicles follow the rearmost
        # vehicle of their query lane across the seam
        rear_pos, rear_vel = (
            ctx if ctx is not None else self.snapshot_ctx(st, cfg, geom)
        )
        q = jnp.clip(query_lane, 0, geom.n_lanes - 1)
        wrap_gap = rear_pos[q] + geom.road_len - st.pos - cfg.vehicle_len
        wrap_dv = st.vel - rear_vel[q]
        a_wrap = idm_accel(
            st.vel, wrap_dv, wrap_gap,
            st.v0, st.T, st.a_max, st.b_comf, st.s0,
        )
        lane_occupied = rear_pos[q] < INF * 0.5
        use_wrap = ~nb.has_lead & lane_occupied
        a = jnp.where(use_wrap, jnp.minimum(a, a_wrap), a)

        # (b) periodic braking pulse inside the band — the wave seed
        period = jnp.maximum(sp.aux1, 1.0)
        phase = jnp.mod(st.t.astype(jnp.float32) * cfg.dt, period)
        pulsing = phase < PERTURB_SECONDS
        in_band = (
            (st.pos >= BAND[0] * geom.road_len)
            & (st.pos <= BAND[1] * geom.road_len)
        )
        a = jnp.where(
            pulsing & in_band, jnp.minimum(a, -sp.aux0), a
        )
        return a

    # ---------------- boundary: wrap, no exits, stopped gauge -------------

    def boundary_clamp(self, st, cfg, geom, pos, vel):
        # ring wrap; inactive slots stay parked at -INF (mod would NaN them)
        pos = jnp.where(st.active, jnp.mod(pos, geom.road_len), pos)
        return pos, vel

    def boundary_exit(self, st, cfg, geom):
        return jnp.zeros_like(st.active)

    def boundary_gauge(self, st, cfg, geom):
        # creeping-or-stopped vehicles: the shockwave-extent measure
        stopped = st.active & (st.vel < 2.0)
        return jnp.sum(stopped.astype(jnp.int32))
