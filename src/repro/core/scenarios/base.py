"""The Scenario API: geometry + parameter sampling + three jit hook groups.

A *scenario* is everything about a simulation workload that is not the core
car-following physics: the road geometry, how an instance's randomized
parameters are drawn, and the scenario-specific rules the otherwise
scenario-agnostic ``sim_step`` (``repro.core.simulator``) applies each step.
GPU-batched simulators win by exactly this separation — one vectorized
physics core, pluggable task definitions — and it is what lets a single
compiled SPMD program sweep a *mix* of workloads (``SweepConfig.scenario_mix``
dispatches per-instance via ``lax.switch`` over registered step hooks).

A scenario implements three hook groups, all jit-compatible (pure functions
of traced arrays; the scenario object itself is static under jit because
``SimConfig`` is a static argument):

``longitudinal_mods(st, cfg, geom, sp, query_lane, nb, a, ctx) -> a``
    Extra acceleration constraints layered onto the base IDM accel *before*
    the ``[-b_max, a_max]`` clamp: the merge ramp's end-wall, a work-zone
    speed limit, a ring road's wrap-around leader, a periodic perturbation.
    ``ctx`` is the scenario's optional ``snapshot_ctx`` result, computed
    once per neighborhood snapshot and shared by all accel queries on it.

``lateral_rules``  (two methods)
    ``mobil_eligible(st, cfg, geom) -> bool[N]`` — which vehicles may make
    discretionary MOBIL lane changes (e.g. ramp vehicles may not), and
    ``lateral_rules(st, cfg, geom, sp, tabs, mobil_lane) -> (lane, n_moves)``
    — scenario-specific *mandatory* moves applied after MOBIL (gap-acceptance
    ramp merge, forced lane-drop exit, vetoes of illegal MOBIL targets).

``boundary``  (four methods)
    ``boundary_spawn(cfg, geom, sp) -> (lam, base_v0, lane_ids)`` — the
    demand process: which lanes spawn, at what rate, at what desired speed;
    ``boundary_clamp(st, cfg, geom, pos, vel)`` — post-integration position
    rules (ramp hard end, ring wrap); ``boundary_exit(st, cfg, geom)`` —
    the exit predicate; ``boundary_gauge(st, cfg, geom)`` — the scenario's
    per-step congestion gauge (reported as ``SimMetrics.ramp_blocked_steps``
    and renamed in records via ``metric_aliases``).

``SimMetrics`` is structurally identical across scenarios (a ``lax.switch``
requirement); ``metric_aliases`` maps the generic field names onto what they
mean for this scenario (e.g. ``merges_ok -> forced_merges`` for lane_drop).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.scenario import ScenarioParams, SimConfig

INF = 1e9


@dataclass(frozen=True)
class RoadGeometry:
    """Static road description a scenario derives from ``SimConfig``.

    Hashable (all-static) so it can parameterize jit-compiled steps.
    """

    n_lanes: int               # main lanes, indices [0, n_lanes)
    road_len: float
    special_lane: str = "none"  # "none" | "ramp" (extra lane n_lanes) |
    #                             "drop" (main lane 0 terminates at zone_end)
    zone_start: float = 0.0    # scenario zone extent (merge zone, bottleneck
    zone_end: float = 0.0      # taper, work zone, perturbation band anchor)
    ring: bool = False         # closed road: positions wrap mod road_len

    @property
    def n_lanes_total(self) -> int:
        """Lane-table size: main lanes plus the ramp lane if present."""
        return self.n_lanes + (1 if self.special_lane == "ramp" else 0)


def gap_acceptance(st, cfg: SimConfig, tabs, target_lane):
    """Per-vehicle mask: are the lead AND follower gaps in ``target_lane``
    acceptable for a mandatory merge? CAVs accept 0.7× gaps (cooperative
    merging). Shared by every scenario with a forced-merge lateral rule."""
    _, lg, hl, _, fg, hf = tabs.query(target_lane)
    front_need = jnp.where(st.is_cav, 0.7, 1.0) * cfg.merge_gap_front
    rear_need = jnp.where(st.is_cav, 0.7, 1.0) * cfg.merge_gap_rear
    return (
        (jnp.where(hl, lg, INF) > front_need)
        & (jnp.where(hf, fg, INF) > rear_need)
    )


def idm_accel(v, dv, gap, v0, T, a_max, b_comf, s0):
    """IDM acceleration. ``dv`` is the closing speed (v_self - v_lead)."""
    gap = jnp.maximum(gap, 0.1)
    s_star = s0 + jnp.maximum(
        0.0, v * T + v * dv / (2.0 * jnp.sqrt(a_max * b_comf))
    )
    free = (v / jnp.maximum(v0, 0.1)) ** 4
    return a_max * (1.0 - free - (s_star / gap) ** 2)


# Virtual dead-end wall: the shared physics of a lane that ends (the merge
# ramp, a lane-drop taper). A standing obstacle at ``wall_pos`` for the
# ``on_wall_lane`` vehicles — IDM braking on approach, a hard position
# clamp, and a "stuck at the wall" congestion gauge.

def end_wall_mods(st, wall_pos, on_wall_lane, a):
    """Brake ``on_wall_lane`` vehicles against a standing wall at
    ``wall_pos`` (layered onto the base accel via min)."""
    wall_gap = wall_pos - st.pos
    a_wall = idm_accel(
        st.vel, st.vel, wall_gap, st.v0, st.T, st.a_max, st.b_comf, st.s0
    )
    return jnp.where(on_wall_lane, jnp.minimum(a, a_wall), a)


def end_wall_clamp(wall_pos, on_wall_lane, pos, vel):
    """Hard dead end: cannot drive past the wall; speed zeroes there."""
    pos = jnp.where(on_wall_lane, jnp.minimum(pos, wall_pos), pos)
    vel = jnp.where(on_wall_lane & (pos >= wall_pos), 0.0, vel)
    return pos, vel


def end_wall_gauge(st, wall_pos, on_wall_lane):
    """Vehicle-steps stopped within 10 m of the wall (starvation gauge)."""
    blocked = (
        st.active & on_wall_lane
        & (st.pos > wall_pos - 10.0) & (st.vel < 0.5)
    )
    return jnp.sum(blocked.astype(jnp.int32))


class Scenario:
    """Base scenario: a plain multi-lane pipe with default everything.

    Subclasses override the hooks they need; the defaults are a straight
    open road — spawn on every main lane at ``lambda_main``, exit past
    ``road_len``, MOBIL everywhere, no extra accel constraints.
    """

    #: registry name (subclasses must set)
    name: str = "base"
    #: generic-metric-field → scenario-meaning renames for records/summaries
    metric_aliases: dict[str, str] = {}

    # ---------------- geometry + parameters ----------------

    def geometry(self, cfg: SimConfig) -> RoadGeometry:
        return RoadGeometry(n_lanes=cfg.n_lanes, road_len=cfg.road_len)

    def sample_params(self, key: jax.Array, cfg: SimConfig) -> ScenarioParams:
        """Draw one instance's randomized parameters (override per scenario)."""
        k1, k2, k3, k4 = jax.random.split(key, 4)
        z = jnp.zeros(())
        lambda_main = jax.random.uniform(
            k1, (cfg.n_lanes,), minval=0.15, maxval=0.55
        )
        p_cav = jax.random.uniform(k2, (), minval=0.0, maxval=1.0)
        v0_mean = jax.random.uniform(k3, (), minval=26.0, maxval=33.0)
        seed = jax.random.randint(k4, (), 0, 2**31 - 1).astype(jnp.uint32)
        return ScenarioParams(
            lambda_main=lambda_main, lambda_ramp=z, p_cav=p_cav,
            v0_mean=v0_mean, v0_ramp=v0_mean, seed=seed, aux0=z, aux1=z,
        )

    # ---------------- hook 1: longitudinal_mods ----------------

    def snapshot_ctx(self, st, cfg: SimConfig, geom: RoadGeometry):
        """Optional scenario state computed ONCE per neighborhood snapshot
        (the simulator builds tables twice per step: pre-move and
        post-change) and passed to every ``longitudinal_mods`` call on that
        snapshot — e.g. the ring's per-lane rearmost-vehicle scan, which
        would otherwise be recomputed for each MOBIL candidate query."""
        return None

    def longitudinal_mods(self, st, cfg: SimConfig, geom: RoadGeometry,
                          sp: ScenarioParams, query_lane, nb, a, ctx=None):
        """Extra accel constraints (pre-clamp). ``nb`` is the Neighbors
        answer for ``query_lane`` (lead/follower indices, gaps, masks);
        ``ctx`` is this snapshot's ``snapshot_ctx`` result."""
        return a

    # ---------------- hook 2: lateral_rules ----------------

    def mobil_eligible(self, st, cfg: SimConfig, geom: RoadGeometry):
        """Vehicles allowed discretionary MOBIL changes (activity and
        cooldown are layered on by the simulator)."""
        return st.lane < geom.n_lanes

    def mobil_candidate_ok(self, st, cfg: SimConfig, geom: RoadGeometry,
                           cand_lane):
        """Per-vehicle mask: may MOBIL move this vehicle into
        ``cand_lane[i]``? Scenario veto of illegal targets (e.g. a closing
        lane) — applied inside the MOBIL decision, so a vetoed move neither
        consumes the lane-change cooldown nor counts as a lane change."""
        return jnp.ones_like(st.active)

    def lateral_rules(self, st, cfg: SimConfig, geom: RoadGeometry,
                      sp: ScenarioParams, tabs, mobil_lane):
        """Mandatory scenario moves after MOBIL. ``st.lane`` is still the
        pre-MOBIL lane; ``mobil_lane`` is MOBIL's proposal. Returns the
        final lane vector and the count of scenario-forced moves (the
        ``merges_ok`` metric delta)."""
        return mobil_lane, jnp.zeros((), jnp.int32)

    # ---------------- hook 3: boundary ----------------

    def boundary_spawn(self, cfg: SimConfig, geom: RoadGeometry,
                       sp: ScenarioParams):
        """Demand process: (arrival rate, base desired speed, lane id) per
        spawn lane. The lane count must be static per scenario."""
        lanes = jnp.arange(geom.n_lanes)
        base_v0 = jnp.full((geom.n_lanes,), 1.0) * sp.v0_mean
        return sp.lambda_main, base_v0, lanes

    def boundary_clamp(self, st, cfg: SimConfig, geom: RoadGeometry,
                       pos, vel):
        """Post-integration position/velocity rules (walls, ring wrap)."""
        return pos, vel

    def boundary_exit(self, st, cfg: SimConfig, geom: RoadGeometry):
        """Exit predicate on the post-integration state."""
        return st.active & (st.pos > geom.road_len)

    def boundary_gauge(self, st, cfg: SimConfig, geom: RoadGeometry):
        """Scenario congestion gauge (vehicle-steps this step); reported as
        the ``ramp_blocked_steps`` metric field, renamed per
        ``metric_aliases``."""
        return jnp.zeros((), jnp.int32)
