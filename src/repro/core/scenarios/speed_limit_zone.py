"""Work-zone speed limit on a straight pipe — throughput under a slow zone.

Geometry::

      lane 2  ──────────────▶ ┊ limit aux0 m/s ┊ ──────────▶
      lane 1  ──────────────▶ ┊                ┊ ──────────▶
      lane 0  ──────────────▶ ┊   work zone    ┊ ──────────▶
                         zone_start        zone_end

A reduced-speed zone spanning all lanes on an otherwise plain highway
(``SimConfig.merge_start/merge_end`` are read as the zone extent). The
zone's limit is the per-instance knob ``aux0`` (sampled 10–18 m/s), so a
sweep covers the limit–throughput response surface.

Hook usage — this scenario is *pure* ``longitudinal_mods``:

- inside the zone, acceleration is capped by IDM's free-road term toward the
  limit speed, so vehicles track the limit instead of their desired ``v0``;
- approaching vehicles anticipate: upstream of ``zone_start`` they follow a
  virtual leader moving at the limit located at the zone entrance — smooth
  deceleration instead of a braking shock at the boundary.

Everything else is the base open-road behavior: MOBIL everywhere, spawn on
every lane, exit past ``road_len``. The gauge counts vehicles inside the
zone (occupancy, → ``zone_veh_steps``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scenario import ScenarioParams, SimConfig
from repro.core.scenarios.base import RoadGeometry, Scenario, idm_accel


class SpeedLimitZone(Scenario):
    name = "speed_limit_zone"
    metric_aliases = {
        "ramp_blocked_steps": "zone_veh_steps",
    }

    def geometry(self, cfg: SimConfig) -> RoadGeometry:
        return RoadGeometry(
            n_lanes=cfg.n_lanes,
            road_len=cfg.road_len,
            zone_start=cfg.merge_start,
            zone_end=cfg.merge_end,
        )

    def sample_params(self, key: jax.Array, cfg: SimConfig) -> ScenarioParams:
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        z = jnp.zeros(())
        lambda_main = jax.random.uniform(
            k1, (cfg.n_lanes,), minval=0.15, maxval=0.55
        )
        p_cav = jax.random.uniform(k2, (), minval=0.0, maxval=1.0)
        v0_mean = jax.random.uniform(k3, (), minval=26.0, maxval=33.0)
        seed = jax.random.randint(k4, (), 0, 2**31 - 1).astype(jnp.uint32)
        limit = jax.random.uniform(k5, (), minval=10.0, maxval=18.0)  # aux0
        return ScenarioParams(
            lambda_main=lambda_main, lambda_ramp=z, p_cav=p_cav,
            v0_mean=v0_mean, v0_ramp=v0_mean, seed=seed, aux0=limit, aux1=z,
        )

    # ---------------- longitudinal: the zone ----------------

    def longitudinal_mods(self, st, cfg, geom, sp, query_lane, nb, a,
                          ctx=None):
        limit = jnp.maximum(sp.aux0, 0.1)

        # inside the zone: free-road IDM toward the limit speed caps accel
        in_zone = (st.pos >= geom.zone_start) & (st.pos <= geom.zone_end)
        a_limit = st.a_max * (1.0 - (st.vel / limit) ** 4)
        a = jnp.where(in_zone, jnp.minimum(a, a_limit), a)

        # upstream anticipation: follow a virtual leader at the zone
        # entrance moving at the limit speed
        before = st.pos < geom.zone_start
        ent_gap = geom.zone_start - st.pos
        a_approach = idm_accel(
            st.vel, st.vel - limit, ent_gap,
            st.v0, st.T, st.a_max, st.b_comf, st.s0,
        )
        a = jnp.where(
            before & (st.vel > limit), jnp.minimum(a, a_approach), a
        )
        return a

    # ---------------- boundary: zone occupancy gauge ----------------

    def boundary_gauge(self, st, cfg, geom):
        in_zone = (
            st.active & (st.pos >= geom.zone_start)
            & (st.pos <= geom.zone_end)
        )
        return jnp.sum(in_zone.astype(jnp.int32))
