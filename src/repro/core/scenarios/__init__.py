"""Scenario registry — the workload catalog behind ``SimConfig.scenario``.

Built-in catalog (name → geometry → hooks exercised → headline metrics):

================== ======================= ========================== =====================
name               geometry                hooks                      scenario metrics
================== ======================= ========================== =====================
highway_merge      3 lanes + on-ramp       long. wall, forced merge,  merges_ok,
                   merge zone              ramp clamp, blockage gauge ramp_blocked_steps
lane_drop          3 lanes, lane 0 ends    long. wall, forced merge   forced_merges,
                   (bottleneck taper)      + MOBIL veto, drop clamp   drop_blocked_steps
stop_and_go        ring road (wraps)       wrap follow, periodic      stopped_steps,
                                           brake pulse, no exits      min_ttc
speed_limit_zone   straight pipe,          zone accel cap +           zone_veh_steps,
                   work zone               anticipatory braking       throughput
================== ======================= ========================== =====================

Registering a custom scenario::

    from repro.core.scenarios import Scenario, register_scenario

    @register_scenario
    class MyScenario(Scenario):
        name = "my_scenario"
        def geometry(self, cfg): ...
        def sample_params(self, key, cfg): ...
        # override whichever of the three hook groups the workload needs

then run it with ``SimConfig(scenario="my_scenario")`` or
``python -m repro.launch.sweep --scenario my_scenario``.

The registry order is stable (insertion order); ``scenario_index`` gives a
scenario's registry-order integer id (useful for labeling datasets). Note
that mixed sweeps select ``lax.switch`` branches by position in the sweep's
own roster (``SweepConfig.scenarios`` / ``SweepState.scenario_id``), which
matches the registry index only when the roster is the full registry in
registration order.
"""

from __future__ import annotations

from repro.core.scenarios.base import (
    INF,
    RoadGeometry,
    Scenario,
    idm_accel,
)

_REGISTRY: dict[str, Scenario] = {}


def register_scenario(cls: type[Scenario]) -> type[Scenario]:
    """Class decorator: instantiate + register a scenario under ``cls.name``."""
    inst = cls()
    if not inst.name or inst.name == "base":
        raise ValueError(f"{cls.__name__} must set a unique `name`")
    if inst.name in _REGISTRY:
        raise ValueError(f"scenario {inst.name!r} already registered")
    _REGISTRY[inst.name] = inst
    return cls


def get_scenario(name: str) -> Scenario:
    """Look up a registered :class:`Scenario` singleton by registry name.

    Raises ``KeyError`` (listing the registered names) for unknown names —
    the error surface for every ``SimConfig.scenario`` typo.
    """
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; registered: {list(_REGISTRY)}"
        ) from None


def list_scenarios() -> list[str]:
    """Registered scenario names, in stable registration order."""
    return list(_REGISTRY)


def scenario_index(name: str) -> int:
    """Registry-order integer id of a registered scenario (stable label).

    NOT the mixed-sweep branch selector: ``SweepState.scenario_id`` indexes
    the sweep's roster (``SweepConfig.scenarios``), not the registry.
    """
    get_scenario(name)
    return list(_REGISTRY).index(name)


# ---- built-in catalog (import order defines the stable ids) --------------

from repro.core.scenarios.highway_merge import HighwayMerge
from repro.core.scenarios.lane_drop import LaneDrop
from repro.core.scenarios.stop_and_go import StopAndGo
from repro.core.scenarios.speed_limit_zone import SpeedLimitZone

register_scenario(HighwayMerge)
register_scenario(LaneDrop)
register_scenario(StopAndGo)
register_scenario(SpeedLimitZone)

__all__ = [
    "INF",
    "RoadGeometry",
    "Scenario",
    "idm_accel",
    "register_scenario",
    "get_scenario",
    "list_scenarios",
    "scenario_index",
    "HighwayMerge",
    "LaneDrop",
    "StopAndGo",
    "SpeedLimitZone",
]
