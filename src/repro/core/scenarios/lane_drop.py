"""Lane-drop bottleneck: the rightmost lane ends; everyone in it must merge.

Geometry::

      lane 2  ──────────────────────────────────────────▶
      lane 1  ──────────────────────────────────────────▶
      lane 0  ───────────────────────╗ taper ╔ (lane ends — merge or stop)
                                zone_start  zone_end

The classic capacity-drop workload: all ``n_lanes`` are main lanes, but lane
0 physically terminates at ``zone_end`` (``SimConfig.merge_start/merge_end``
are read as the taper extent). Hook usage:

- ``longitudinal_mods`` — lane-0 vehicles brake against a virtual wall at
  the taper end (the same IDM-against-standing-obstacle trick as the ramp);
- ``lateral_rules`` — inside the taper, lane-0 vehicles take a mandatory
  gap-acceptance merge into lane 1 (CAVs accept 0.7× gaps); MOBIL moves
  *into* lane 0 are vetoed once past ``zone_start`` (the lane is closing);
- ``boundary`` — spawning on all main lanes at ``lambda_main``; lane-0
  position clamps at the taper end; the gauge counts vehicles stuck there.

Forced merges are reported in the ``merges_ok`` metric slot
(→ ``forced_merges``), blockage in ``ramp_blocked_steps``
(→ ``drop_blocked_steps``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scenario import ScenarioParams, SimConfig
from repro.core.scenarios.base import (
    RoadGeometry,
    Scenario,
    end_wall_clamp,
    end_wall_gauge,
    end_wall_mods,
    gap_acceptance,
)

DROP_LANE = 0          # the terminating lane
TARGET_LANE = 1        # where its traffic must go


class LaneDrop(Scenario):
    name = "lane_drop"
    metric_aliases = {
        "merges_ok": "forced_merges",
        "ramp_blocked_steps": "drop_blocked_steps",
    }

    def geometry(self, cfg: SimConfig) -> RoadGeometry:
        if cfg.n_lanes < 2:
            raise ValueError(
                "lane_drop needs n_lanes >= 2: lane 0 terminates and its "
                f"traffic merges into lane {TARGET_LANE}"
            )
        return RoadGeometry(
            n_lanes=cfg.n_lanes,
            road_len=cfg.road_len,
            special_lane="drop",
            zone_start=cfg.merge_start,
            zone_end=cfg.merge_end,
        )

    def sample_params(self, key: jax.Array, cfg: SimConfig) -> ScenarioParams:
        k1, k2, k3, k4 = jax.random.split(key, 4)
        z = jnp.zeros(())
        # heavier demand than the merge — the bottleneck is the point
        lambda_main = jax.random.uniform(
            k1, (cfg.n_lanes,), minval=0.25, maxval=0.65
        )
        p_cav = jax.random.uniform(k2, (), minval=0.0, maxval=1.0)
        v0_mean = jax.random.uniform(k3, (), minval=26.0, maxval=33.0)
        seed = jax.random.randint(k4, (), 0, 2**31 - 1).astype(jnp.uint32)
        return ScenarioParams(
            lambda_main=lambda_main, lambda_ramp=z, p_cav=p_cav,
            v0_mean=v0_mean, v0_ramp=v0_mean, seed=seed, aux0=z, aux1=z,
        )

    # ---------------- longitudinal: taper-end wall for lane 0 -------------

    def longitudinal_mods(self, st, cfg, geom, sp, query_lane, nb, a,
                          ctx=None):
        return end_wall_mods(st, geom.zone_end, query_lane == DROP_LANE, a)

    # ---------------- lateral: forced exit from the dying lane ------------

    def mobil_candidate_ok(self, st, cfg, geom, cand_lane):
        # no discretionary moves INTO the drop lane once it is closing
        # (vetoed inside the MOBIL decision: no cooldown, no metric count)
        into_closing = (
            (cand_lane == DROP_LANE) & (st.lane != DROP_LANE)
            & (st.pos >= geom.zone_start)
        )
        return ~into_closing

    def lateral_rules(self, st, cfg, geom, sp, tabs, mobil_lane):
        # mandatory gap-acceptance merge out of lane 0 inside the taper
        must_merge = (st.lane == DROP_LANE) & st.active
        in_zone = (st.pos >= geom.zone_start) & (st.pos <= geom.zone_end)
        target = jnp.full_like(st.lane, TARGET_LANE)
        gap_ok = gap_acceptance(st, cfg, tabs, target)
        merge = must_merge & in_zone & gap_ok
        lane = jnp.where(merge, TARGET_LANE, mobil_lane)
        return lane, jnp.sum(merge.astype(jnp.int32))

    # ---------------- boundary ----------------

    def boundary_clamp(self, st, cfg, geom, pos, vel):
        return end_wall_clamp(geom.zone_end, st.lane == DROP_LANE, pos, vel)

    def boundary_gauge(self, st, cfg, geom):
        return end_wall_gauge(st, geom.zone_end, st.lane == DROP_LANE)
