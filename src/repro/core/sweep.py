"""The PBS-job-array analogue: a sharded, chunked, restartable simulation sweep.

Paper mapping (DESIGN.md §2):

- ``#PBS -J 1-N`` job array            → an ``[N, ...]`` instance axis sharded
  over every device of the mesh (`shard_map`-style data parallelism; the
  instances are independent so the hot loop has zero collectives).
- 15-minute walltime slices            → ``chunk_steps`` physics steps per
  ``run_chunk`` call; sweep state is checkpointable at every chunk boundary.
- PBS completion accounting            → a per-instance ``done`` bitmap; the
  run loop continues until completion is 100 % (the paper's §5.2 metric),
  surviving injected node failures (``repro.core.fault``).
- straggler mitigation                 → instances have per-instance horizons
  (variable cost); **compaction** re-packs unfinished instances onto all
  devices between chunks so finished slots stop burning lockstep compute.
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.scenario import SimConfig, ScenarioParams
from repro.core.scenarios import get_scenario
from repro.core.simulator import (
    SimState,
    SimMetrics,
    init_state,
    rollout_chunk,
)


@dataclass(frozen=True)
class SweepConfig:
    n_instances: int = 48          # the paper's experiment: 6 nodes x 8 = 48
    steps_per_instance: int = 9000 # 15 sim-minutes at dt=0.1
    chunk_steps: int = 1500        # one "walltime slice"
    sim: SimConfig = SimConfig()
    seed: int = 0
    vary_horizon: bool = False     # straggler population: horizons in
    min_horizon_frac: float = 0.5  # [frac*steps, steps]
    compaction: bool = True        # straggler mitigation (see module docstring)
    # mixed-scenario sweep: when non-empty, instances are assigned these
    # registered scenarios round-robin and the chunk program dispatches
    # per-instance via lax.switch — shapes stay static, ONE compile serves
    # the whole mix. Empty = every instance runs sim.scenario (no switch,
    # zero overhead). Cost note: vmapping a switch over a batched selector
    # executes every branch and select_n's the results, so a k-scenario mix
    # does up to k× the per-chunk step work; grouping instances by scenario
    # into separate (per-scenario-compiled) chunk calls is the optimization
    # path if mixed-sweep throughput becomes the bottleneck (ROADMAP).
    scenario_mix: tuple[str, ...] = ()
    # the neighborhood engine is selected per-instance-config via
    # sim.neighbor_impl (see repro.core.neighbors / launch.sweep --neighbor-impl)

    @property
    def scenarios(self) -> tuple[str, ...]:
        """The effective scenario roster (mix, or the single sim scenario)."""
        return tuple(self.scenario_mix) or (self.sim.scenario,)


class SweepState(NamedTuple):
    """Checkpointable sweep state. All arrays have a leading [N] axis."""

    sim: SimState          # stacked per-instance simulator states
    metrics: SimMetrics    # stacked per-instance accumulators
    params: ScenarioParams # stacked per-instance scenario draws
    horizon: jax.Array     # [N] i32
    done: jax.Array        # [N] bool — the completion bitmap
    chunk: jax.Array       # [] i32 — walltime slices executed
    scenario_id: jax.Array # [N] i32 — index into SweepConfig.scenarios


def _instance_sharding(mesh: Mesh | None):
    if mesh is None:
        return None
    return NamedSharding(mesh, P(mesh.axis_names))  # instance axis over all


class SweepRunner:
    """Drives a sweep to 100 % completion in walltime-slice chunks."""

    def __init__(self, cfg: SweepConfig, mesh: Mesh | None = None) -> None:
        self.cfg = cfg
        self.mesh = mesh
        self.sharding = _instance_sharding(mesh)
        # one SimConfig per roster entry; every branch shares shapes, so a
        # mixed sweep still compiles into a single SPMD program
        self._sims = tuple(
            dataclasses.replace(cfg.sim, scenario=s) for s in cfg.scenarios
        )
        if len(self._sims) == 1:
            sim0 = self._sims[0]

            def chunk_one(st, m, sp, h, sid):
                return rollout_chunk(st, m, sp, h, sim0, cfg.chunk_steps)
        else:
            branches = tuple(
                functools.partial(rollout_chunk, cfg=s, n_steps=cfg.chunk_steps)
                for s in self._sims
            )

            def chunk_one(st, m, sp, h, sid):
                return jax.lax.switch(sid, branches, st, m, sp, h)

        self._chunk_fn = jax.jit(jax.vmap(chunk_one))

    # ---------------- init ----------------

    def init(self) -> SweepState:
        cfg = self.cfg
        sims = self._sims
        base = jax.random.key(cfg.seed)

        def init_one(i):
            k = jax.random.fold_in(base, i)
            sid = jnp.asarray(i % len(sims), jnp.int32)
            k_sp = jax.random.fold_in(k, 1)
            if len(sims) == 1:
                sp = get_scenario(sims[0].scenario).sample_params(k_sp, sims[0])
            else:
                sp = jax.lax.switch(
                    sid,
                    tuple(
                        functools.partial(get_scenario(s.scenario).sample_params,
                                          cfg=s)
                        for s in sims
                    ),
                    k_sp,
                )
            st = init_state(cfg.sim, jax.random.fold_in(k, 2))
            if cfg.vary_horizon:
                frac = jax.random.uniform(
                    jax.random.fold_in(k, 3), (),
                    minval=cfg.min_horizon_frac, maxval=1.0,
                )
                horizon = (frac * cfg.steps_per_instance).astype(jnp.int32)
            else:
                horizon = jnp.asarray(cfg.steps_per_instance, jnp.int32)
            return st, SimMetrics.zeros(), sp, horizon, sid

        ids = jnp.arange(cfg.n_instances)
        sim, metrics, params, horizon, sids = jax.jit(jax.vmap(init_one))(ids)
        state = SweepState(
            sim=sim,
            metrics=metrics,
            params=params,
            horizon=horizon,
            done=jnp.zeros((cfg.n_instances,), bool),
            chunk=jnp.zeros((), jnp.int32),
            scenario_id=sids,
        )
        return self._place(state)

    def _place(self, state: SweepState) -> SweepState:
        if self.sharding is None:
            return state
        shard = self.sharding

        def put(x):
            if getattr(x, "ndim", 0) >= 1 and x.shape[0] == self.cfg.n_instances:
                return jax.device_put(x, shard)
            return x

        return jax.tree.map(put, state)

    # ---------------- one walltime slice ----------------

    def run_chunk(self, state: SweepState) -> SweepState:
        cfg = self.cfg
        if cfg.compaction:
            state = self._run_chunk_compacted(state)
        else:
            sim, metrics = self._chunk_fn(
                state.sim, state.metrics, state.params, state.horizon,
                state.scenario_id,
            )
            state = state._replace(sim=sim, metrics=metrics)
        done = state.sim.t >= state.horizon
        return state._replace(done=done, chunk=state.chunk + 1)

    def _run_chunk_compacted(self, state: SweepState) -> SweepState:
        """Straggler mitigation: advance only unfinished instances.

        Unfinished instances are gathered into a dense prefix (padded to the
        worker count), stepped, and scattered back. Finished instances stop
        consuming lockstep compute — all devices keep working as long as any
        instance remains (DESIGN.md §7).
        """
        done = np.asarray(jax.device_get(state.done))
        pending = np.flatnonzero(~done)
        if pending.size == 0:
            return state
        n_workers = (
            len(self.mesh.devices.flat) if self.mesh is not None else 1
        )
        pad = (-pending.size) % max(n_workers, 1)
        idx = np.concatenate([pending, pending[: 1].repeat(pad)])
        take = jnp.asarray(idx)

        sub = jax.tree.map(
            lambda x: x[take],
            (state.sim, state.metrics, state.params, state.horizon,
             state.scenario_id),
        )
        sim, metrics = self._chunk_fn(*sub[:2], sub[2], sub[3], sub[4])
        # drop padding rows, scatter results back to logical slots
        keep = pending.size
        upd = jnp.asarray(pending)

        def scatter(full, part):
            return full.at[upd].set(part[:keep])

        new_sim = jax.tree.map(scatter, state.sim, sim)
        new_metrics = jax.tree.map(scatter, state.metrics, metrics)
        return state._replace(sim=new_sim, metrics=new_metrics)

    # ---------------- full run with fault handling ----------------

    def run(
        self,
        state: SweepState | None = None,
        max_chunks: int = 10_000,
        on_chunk: Callable[[int, SweepState], SweepState] | None = None,
    ) -> SweepState:
        """Run until the completion bitmap is all-true.

        ``on_chunk(chunk_idx, state) -> state`` is the fault-injection /
        checkpoint hook: it may revert instances (simulated node failure) or
        persist state. The loop re-schedules whatever remains incomplete —
        completion always reaches 100 % (paper §5.2).
        """
        if state is None:
            state = self.init()
        for c in range(max_chunks):
            if bool(jax.device_get(jnp.all(state.done))):
                break
            state = self.run_chunk(state)
            if on_chunk is not None:
                state = on_chunk(c, state)
        return state

    # ---------------- elastic re-meshing ----------------

    def remesh(self, state: SweepState, mesh: Mesh | None) -> SweepState:
        """Move a sweep onto a different mesh (elastic scale up/down)."""
        self.mesh = mesh
        self.sharding = _instance_sharding(mesh)
        return self._place(state)


def completion_rate(state: SweepState) -> float:
    return float(jax.device_get(jnp.mean(state.done.astype(jnp.float32))))
