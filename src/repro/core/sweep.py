"""The PBS-job-array analogue: a sharded, chunked, restartable simulation sweep.

Paper mapping (DESIGN.md §2):

- ``#PBS -J 1-N`` job array            → an ``[N, ...]`` instance axis sharded
  over every device of the mesh (`shard_map`-style data parallelism; the
  instances are independent so the hot loop has zero collectives).
- 15-minute walltime slices            → ``chunk_steps`` physics steps per
  ``run_chunk`` call; sweep state is checkpointable at every chunk boundary.
- PBS completion accounting            → a per-instance ``done`` bitmap; the
  run loop continues until completion is 100 % (the paper's §5.2 metric),
  surviving injected node failures (``repro.core.fault``).
- straggler mitigation                 → instances have per-instance horizons
  (variable cost); **compaction** re-packs unfinished instances onto all
  devices between chunks so finished slots stop burning lockstep compute.

Dispatch modes (``SweepConfig.dispatch``) — how a mixed-scenario chunk is
mapped onto compiled programs:

- ``"switch"``  — ONE compiled program: every instance runs a vmapped
  ``lax.switch`` over the scenario roster. Batching a switch executes *every*
  branch and ``select_n``'s the results, so a k-scenario mix pays up to k×
  the per-chunk step work. Kept as the single-compile fallback and as the
  parity oracle for ``grouped``.
- ``"grouped"`` — the **chunk execution planner** partitions the pending
  instances by ``scenario_id`` on the host, pads each group to the worker
  count (padding rows are drawn from already-finished instances, whose
  results are discarded), runs each group through its *per-scenario* jitted
  chunk fn (no switch — each instance executes exactly one branch), and
  scatters results back to logical slots. One compile per distinct roster
  SimConfig, cached across chunks. This is the same host-side repacking
  trick straggler compaction already uses, so the two are unified into one
  plan: compaction decides *which* instances are live, grouping decides how
  the live set is split into dense per-program batches.
- ``"auto"``    — ``grouped`` when the roster has >1 scenario, else
  ``switch`` (which for a single scenario is a direct call, no switch op).

Both modes are bit-for-bit trajectory-equivalent (tested); ``grouped``
recovers the k× redundancy on mixed sweeps (see BENCH_sweep.json ``mixed``).
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.record import RecordConfig, TraceBuffer, batch_zeros
from repro.core.scenario import SimConfig, ScenarioParams
from repro.core.scenarios import get_scenario
from repro.core.simulator import (
    SimState,
    SimMetrics,
    init_state,
    rollout_chunk_rec,
)

DISPATCH_MODES = ("auto", "switch", "grouped")


@dataclass(frozen=True)
class SweepConfig:
    n_instances: int = 48          # the paper's experiment: 6 nodes x 8 = 48
    steps_per_instance: int = 9000 # 15 sim-minutes at dt=0.1
    chunk_steps: int = 1500        # one "walltime slice"
    sim: SimConfig = SimConfig()
    seed: int = 0
    vary_horizon: bool = False     # straggler population: horizons in
    min_horizon_frac: float = 0.5  # [frac*steps, steps]
    compaction: bool = True        # straggler mitigation (see module docstring)
    # mixed-scenario sweep: when non-empty, instances are assigned these
    # registered scenarios round-robin. How the mix is executed is governed
    # by ``dispatch`` (see module docstring): "switch" runs every branch per
    # instance inside one compile (k× step work for a k-scenario mix);
    # "grouped" repacks instances per scenario into dense per-scenario
    # compiled calls. Empty mix = every instance runs sim.scenario.
    scenario_mix: tuple[str, ...] = ()
    dispatch: str = "auto"         # "switch" | "grouped" | "auto"
    # the neighborhood engine is selected per-instance-config via
    # sim.neighbor_impl (see repro.core.neighbors / launch.sweep --neighbor-impl)
    # trajectory recording (repro.core.record): None = terminal metrics only;
    # a RecordConfig makes every chunk also fill SweepState.trace — the
    # per-instance time series the Phase-III dataset pipeline shards out
    record: RecordConfig | None = None

    @property
    def scenarios(self) -> tuple[str, ...]:
        """The effective scenario roster (mix, or the single sim scenario)."""
        return tuple(self.scenario_mix) or (self.sim.scenario,)

    @property
    def effective_dispatch(self) -> str:
        """Resolve "auto": grouped pays off exactly when the roster is mixed."""
        if self.dispatch == "auto":
            return "grouped" if len(self.scenarios) > 1 else "switch"
        return self.dispatch


class SweepState(NamedTuple):
    """Checkpointable sweep state. All arrays have a leading [N] axis.

    The leading axis is always in LOGICAL instance order: the planner's
    gather/scatter repacking is confined to the inside of ``run_chunk``, so
    checkpoints, failure masks, and aggregation never see physical rows.
    """

    sim: SimState          # stacked per-instance simulator states
    metrics: SimMetrics    # stacked per-instance accumulators
    params: ScenarioParams # stacked per-instance scenario draws
    horizon: jax.Array     # [N] i32
    done: jax.Array        # [N] bool — the completion bitmap
    chunk: jax.Array       # [] i32 — walltime slices executed
    scenario_id: jax.Array # [N] i32 — index into SweepConfig.scenarios
    # recorded time series ([N]-stacked TraceBuffer) when
    # SweepConfig.record is set, else None (an empty pytree subtree, so
    # every tree.map/checkpoint/revert path handles both transparently)
    trace: TraceBuffer | None = None


@dataclass(frozen=True)
class GroupPlan:
    """One dense batch of a chunk execution plan.

    ``take[:keep]`` are the logical ids whose results are kept; rows past
    ``keep`` are padding (already-done instances when any exist — their
    rollout is a horizon-masked no-op and the results are discarded).
    """

    roster: int        # index into SweepConfig.scenarios; -1 = mixed (switch)
    take: np.ndarray   # [P] logical ids to gather, padded to worker multiple
    keep: int          # number of real (non-padding) rows
    identity: bool     # take == arange(N): gather/scatter can be skipped


def _pad_group(idx: np.ndarray, pad_pool: np.ndarray, n_workers: int):
    """Pad ``idx`` to a multiple of the worker count.

    Padding rows come from ``pad_pool`` (finished instances, cycled) so no
    live instance is stepped twice per chunk; only when nothing has finished
    yet do we fall back to repeating the group's first live instance. Either
    way the padding rows' results are dropped by the scatter.
    """
    pad = (-idx.size) % max(n_workers, 1)
    if pad == 0:
        return idx, idx.size
    fill_src = pad_pool if pad_pool.size else idx[:1]
    fill = np.resize(fill_src, pad)
    return np.concatenate([idx, fill]), idx.size


def plan_chunk(
    done: np.ndarray,
    scenario_ids: np.ndarray,
    n_workers: int,
    *,
    grouped: bool,
    compaction: bool,
) -> list[GroupPlan]:
    """Build the host-side execution plan for one chunk.

    Unifies straggler compaction and scenario grouping: ``compaction``
    selects the live set (pending instances only vs. everyone), ``grouped``
    splits the live set into one dense batch per roster entry. Returns an
    empty plan when nothing is pending.
    """
    n = done.size
    live = np.flatnonzero(~done) if compaction else np.arange(n)
    if live.size == 0:
        return []
    pad_pool = np.flatnonzero(done)
    if grouped:
        rosters = np.unique(scenario_ids[live])
        groups = [(int(r), live[scenario_ids[live] == r]) for r in rosters]
    else:
        groups = [(-1, live)]
    plans = []
    for roster, idx in groups:
        take, keep = _pad_group(idx, pad_pool, n_workers)
        identity = take.size == n and keep == n and np.array_equal(
            take, np.arange(n)
        )
        plans.append(GroupPlan(roster=roster, take=take, keep=keep,
                               identity=identity))
    return plans


def _instance_sharding(mesh: Mesh | None):
    if mesh is None:
        return None
    return NamedSharding(mesh, P(mesh.axis_names))  # instance axis over all


class SweepRunner:
    """Drives a sweep to 100 % completion in walltime-slice chunks."""

    def __init__(self, cfg: SweepConfig, mesh: Mesh | None = None) -> None:
        if cfg.dispatch not in DISPATCH_MODES:
            raise ValueError(
                f"dispatch must be one of {DISPATCH_MODES}, got {cfg.dispatch!r}"
            )
        self.cfg = cfg
        self.mesh = mesh
        self.sharding = _instance_sharding(mesh)
        self.dispatch = cfg.effective_dispatch
        # one SimConfig per roster entry; every branch shares shapes, so the
        # switch path compiles a mixed sweep into a single SPMD program
        self._sims = tuple(
            dataclasses.replace(cfg.sim, scenario=s) for s in cfg.scenarios
        )
        # every chunk fn threads the trace (None when recording is off); the
        # RecordConfig is shared by all roster entries so lax.switch branches
        # return identical trees
        rec = cfg.record
        if len(self._sims) == 1:
            sim0 = self._sims[0]

            def chunk_one(st, m, sp, h, tr, sid):
                return rollout_chunk_rec(
                    st, m, sp, h, tr, sim0, cfg.chunk_steps, rec
                )
        else:
            branches = tuple(
                functools.partial(rollout_chunk_rec, cfg=s,
                                  n_steps=cfg.chunk_steps, rec=rec)
                for s in self._sims
            )

            def chunk_one(st, m, sp, h, tr, sid):
                return jax.lax.switch(sid, branches, st, m, sp, h, tr)

        self._chunk_fn = jax.jit(jax.vmap(chunk_one))
        # per-roster switch-free chunk fns for grouped dispatch, deduped by
        # SimConfig so a weighted mix (same scenario listed twice) shares one
        # compile cache entry; jit itself caches across chunks per shape
        by_sim: dict[SimConfig, Callable] = {}
        for s in self._sims:
            if s not in by_sim:
                by_sim[s] = jax.jit(jax.vmap(functools.partial(
                    rollout_chunk_rec, cfg=s, n_steps=cfg.chunk_steps, rec=rec
                )))
        self._roster_fns = tuple(by_sim[s] for s in self._sims)

    # ---------------- init ----------------

    def init(self) -> SweepState:
        cfg = self.cfg
        sims = self._sims
        base = jax.random.key(cfg.seed)

        def init_one(i):
            k = jax.random.fold_in(base, i)
            sid = jnp.asarray(i % len(sims), jnp.int32)
            k_sp = jax.random.fold_in(k, 1)
            if len(sims) == 1:
                sp = get_scenario(sims[0].scenario).sample_params(k_sp, sims[0])
            else:
                sp = jax.lax.switch(
                    sid,
                    tuple(
                        functools.partial(get_scenario(s.scenario).sample_params,
                                          cfg=s)
                        for s in sims
                    ),
                    k_sp,
                )
            st = init_state(cfg.sim, jax.random.fold_in(k, 2))
            if cfg.vary_horizon:
                frac = jax.random.uniform(
                    jax.random.fold_in(k, 3), (),
                    minval=cfg.min_horizon_frac, maxval=1.0,
                )
                horizon = (frac * cfg.steps_per_instance).astype(jnp.int32)
            else:
                horizon = jnp.asarray(cfg.steps_per_instance, jnp.int32)
            return st, SimMetrics.zeros(), sp, horizon, sid

        ids = jnp.arange(cfg.n_instances)
        sim, metrics, params, horizon, sids = jax.jit(jax.vmap(init_one))(ids)
        trace = (
            batch_zeros(cfg.record, cfg.steps_per_instance, cfg.n_instances)
            if cfg.record is not None
            else None
        )
        state = SweepState(
            sim=sim,
            metrics=metrics,
            params=params,
            horizon=horizon,
            done=jnp.zeros((cfg.n_instances,), bool),
            chunk=jnp.zeros((), jnp.int32),
            scenario_id=sids,
            trace=trace,
        )
        return self._place(state)

    def _place(self, state: SweepState) -> SweepState:
        if self.sharding is None:
            return state
        shard = self.sharding

        def put(x):
            if getattr(x, "ndim", 0) >= 1 and x.shape[0] == self.cfg.n_instances:
                return jax.device_put(x, shard)
            return x

        return jax.tree.map(put, state)

    def _n_workers(self) -> int:
        return len(self.mesh.devices.flat) if self.mesh is not None else 1

    # ---------------- one walltime slice ----------------

    def plan_chunk(self, state: SweepState) -> list[GroupPlan]:
        """The chunk execution plan for the current completion bitmap."""
        cfg = self.cfg
        grouped = self.dispatch == "grouped"
        if not cfg.compaction and not grouped:
            # full-width switch program: no repacking needed
            n = cfg.n_instances
            return [GroupPlan(roster=-1, take=np.arange(n), keep=n,
                              identity=True)]
        # partition on the state's own assignment (not an assumed round-robin)
        # so grouped dispatch honors whatever scenario_id a restored or
        # hand-built state carries, like the switch program does — except
        # that lax.switch silently clamps out-of-range ids; here that would
        # mean stepping an instance with the wrong scenario's physics, so
        # reject it loudly (it only happens on config drift at restore time)
        done, sids = jax.device_get((state.done, state.scenario_id))
        done, sids = np.asarray(done), np.asarray(sids)
        if sids.size and (sids.min() < 0 or sids.max() >= len(self._sims)):
            raise ValueError(
                f"state.scenario_id out of range for a {len(self._sims)}-"
                f"entry roster {self.cfg.scenarios} — was this state "
                "restored from a sweep with a different scenario_mix?"
            )
        return plan_chunk(done, sids, self._n_workers(),
                          grouped=grouped, compaction=cfg.compaction)

    def run_chunk(self, state: SweepState) -> SweepState:
        for plan in self.plan_chunk(state):
            state = self._run_group(state, plan)
        done = state.sim.t >= state.horizon
        return state._replace(done=done, chunk=state.chunk + 1)

    def _run_group(self, state: SweepState, plan: GroupPlan) -> SweepState:
        """Gather one plan group, step it, scatter results to logical slots.

        The trace buffer rides the same gather/scatter as sim/metrics
        (``state.trace`` is None when recording is off — an empty subtree
        every tree.map here passes through untouched), which is what makes
        recording dispatch-agnostic by construction.
        """
        fn = self._chunk_fn if plan.roster < 0 else self._roster_fns[plan.roster]
        if plan.identity:
            args = (state.sim, state.metrics, state.params, state.horizon,
                    state.trace)
            sim, metrics, trace = (
                fn(*args, state.scenario_id) if plan.roster < 0 else fn(*args)
            )
            return state._replace(sim=sim, metrics=metrics, trace=trace)
        take = jnp.asarray(plan.take)
        sub = jax.tree.map(
            lambda x: x[take],
            (state.sim, state.metrics, state.params, state.horizon,
             state.trace),
        )
        if plan.roster < 0:
            sim, metrics, trace = self._chunk_fn(*sub, state.scenario_id[take])
        else:
            sim, metrics, trace = fn(*sub)
        # drop padding rows, scatter results back to logical slots
        keep = plan.keep
        upd = jnp.asarray(plan.take[:keep])

        def scatter(full, part):
            return full.at[upd].set(part[:keep])

        return state._replace(
            sim=jax.tree.map(scatter, state.sim, sim),
            metrics=jax.tree.map(scatter, state.metrics, metrics),
            trace=jax.tree.map(scatter, state.trace, trace),
        )

    # ---------------- full run with fault handling ----------------

    def run(
        self,
        state: SweepState | None = None,
        max_chunks: int = 10_000,
        on_chunk: Callable[[int, SweepState], SweepState] | None = None,
    ) -> SweepState:
        """Run until the completion bitmap is all-true.

        ``on_chunk(chunk_idx, state) -> state`` is the fault-injection /
        checkpoint hook: it may revert instances (simulated node failure) or
        persist state. The loop re-schedules whatever remains incomplete —
        completion always reaches 100 % (paper §5.2).
        """
        if state is None:
            state = self.init()
        for c in range(max_chunks):
            if bool(jax.device_get(jnp.all(state.done))):
                break
            state = self.run_chunk(state)
            if on_chunk is not None:
                state = on_chunk(c, state)
        return state

    # ---------------- elastic re-meshing ----------------

    def remesh(self, state: SweepState, mesh: Mesh | None) -> SweepState:
        """Move a sweep onto a different mesh (elastic scale up/down)."""
        self.mesh = mesh
        self.sharding = _instance_sharding(mesh)
        return self._place(state)


def completion_rate(state: SweepState) -> float:
    return float(jax.device_get(jnp.mean(state.done.astype(jnp.float32))))
