"""The PBS-job-array analogue: a sharded, chunked, restartable simulation sweep.

Paper mapping (DESIGN.md §2):

- ``#PBS -J 1-N`` job array            → an ``[N, ...]`` instance axis sharded
  over every device of the mesh (`shard_map`-style data parallelism; the
  instances are independent so the hot loop has zero collectives).
- 15-minute walltime slices            → ``chunk_steps`` physics steps per
  ``run_chunk`` call; sweep state is checkpointable at every chunk boundary.
- PBS completion accounting            → a per-instance ``done`` bitmap; the
  run loop continues until completion is 100 % (the paper's §5.2 metric),
  surviving injected node failures (``repro.core.fault``).
- straggler mitigation                 → instances have per-instance horizons
  (variable cost); **compaction** re-packs unfinished instances onto all
  devices between chunks so finished slots stop burning lockstep compute.

Dispatch modes (``SweepConfig.dispatch``) — how a mixed-scenario chunk is
mapped onto compiled programs:

- ``"switch"``  — ONE compiled program: every instance runs a vmapped
  ``lax.switch`` over the scenario roster. Batching a switch executes *every*
  branch and ``select_n``'s the results, so a k-scenario mix pays up to k×
  the per-chunk step work. Kept as the single-compile fallback and as the
  parity oracle for ``grouped``.
- ``"grouped"`` — the **chunk execution planner** partitions the pending
  instances by ``scenario_id`` on the host, pads each group to the worker
  count (padding rows are drawn from already-finished instances, whose
  results are discarded), runs each group through its *per-scenario* jitted
  chunk fn (no switch — each instance executes exactly one branch), and
  scatters results back to logical slots. One compile per distinct roster
  SimConfig, cached across chunks. This is the same host-side repacking
  trick straggler compaction already uses, so the two are unified into one
  plan: compaction decides *which* instances are live, grouping decides how
  the live set is split into dense per-program batches.
- ``"auto"``    — ``grouped`` when the roster has >1 scenario, else
  ``switch`` (which for a single scenario is a direct call, no switch op).

Both modes are bit-for-bit trajectory-equivalent (tested); ``grouped``
recovers the k× redundancy on mixed sweeps (see BENCH_sweep.json ``mixed``).

Device sharding (the paper's "across an arbitrary number of computing
nodes"): given a :class:`jax.sharding.Mesh` with **D > 1** devices, the
runner stops issuing one global (or per-scenario) call and instead plans
**per-device blocks**: :func:`plan_chunk_blocks` packs the per-scenario
groups onto devices with LPT (longest-processing-time-first, the same
heuristic the paper uses to pack simulation jobs onto nodes), splitting a
group across devices only when it exceeds a device's fair share
``ceil(live / D)``. The chunk is then ONE sharded call
(``shard_map`` over the instance axis): every device receives its
``cap``-row block plus a scalar ``block_sid`` and runs a *scalar*
``lax.switch`` — an HLO conditional that executes only that device's
scenario branch at runtime — so heterogeneous scenarios run concurrently
on different devices with no cross-device communication inside the chunk
and no vmapped-switch tax. Blocks that must mix scenarios (more groups
than capacity allows) carry ``block_sid = -1`` and fall back to the
per-row vmapped switch for that block only. The host-side gather/scatter
at the chunk boundary is the only data movement, and every
:class:`SweepState` stays in logical instance order — so recording,
fault masks, checkpoints and aggregation are sharding-agnostic by
construction, and 1-device and N-device runs are bit-for-bit identical
(tests/test_sharded.py).
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.record import RecordConfig, TraceBuffer, batch_zeros
from repro.core.scenario import SimConfig, ScenarioParams
from repro.core.scenarios import get_scenario
from repro.core.simulator import (
    SimState,
    SimMetrics,
    init_state,
    rollout_chunk_rec,
)

DISPATCH_MODES = ("auto", "switch", "grouped")


@dataclass(frozen=True)
class SweepConfig:
    """Static description of one sweep — the paper's batch-job submission.

    ``n_instances`` independent simulations, each running
    ``steps_per_instance`` physics steps (or its own drawn horizon when
    ``vary_horizon``), executed in ``chunk_steps``-step walltime slices.
    ``dispatch`` picks how a mixed-scenario chunk maps onto compiled
    programs: ``"switch"`` = ONE vmapped ``lax.switch`` program (every
    branch executes for every instance — up to k× step work on a
    k-scenario mix; the parity oracle), ``"grouped"`` = the chunk planner
    repacks instances per scenario into dense switch-free calls (and into
    per-device LPT blocks on a multi-device mesh), ``"auto"`` = grouped
    iff the roster is mixed. All modes are bit-for-bit
    trajectory-equivalent. ``record`` (a
    :class:`~repro.core.record.RecordConfig`) turns on the Phase-III
    trajectory channel. The config is hashable (a jit compile-time
    constant) and fully determines the sweep together with ``seed``.
    """

    n_instances: int = 48          # the paper's experiment: 6 nodes x 8 = 48
    steps_per_instance: int = 9000 # 15 sim-minutes at dt=0.1
    chunk_steps: int = 1500        # one "walltime slice"
    sim: SimConfig = SimConfig()
    seed: int = 0
    vary_horizon: bool = False     # straggler population: horizons in
    min_horizon_frac: float = 0.5  # [frac*steps, steps]
    compaction: bool = True        # straggler mitigation (see module docstring)
    # mixed-scenario sweep: when non-empty, instances are assigned these
    # registered scenarios round-robin. How the mix is executed is governed
    # by ``dispatch`` (see module docstring): "switch" runs every branch per
    # instance inside one compile (k× step work for a k-scenario mix);
    # "grouped" repacks instances per scenario into dense per-scenario
    # compiled calls. Empty mix = every instance runs sim.scenario.
    scenario_mix: tuple[str, ...] = ()
    dispatch: str = "auto"         # "switch" | "grouped" | "auto"
    # the neighborhood engine is selected per-instance-config via
    # sim.neighbor_impl (see repro.core.neighbors / launch.sweep --neighbor-impl)
    # trajectory recording (repro.core.record): None = terminal metrics only;
    # a RecordConfig makes every chunk also fill SweepState.trace — the
    # per-instance time series the Phase-III dataset pipeline shards out
    record: RecordConfig | None = None

    @property
    def scenarios(self) -> tuple[str, ...]:
        """The effective scenario roster (mix, or the single sim scenario)."""
        return tuple(self.scenario_mix) or (self.sim.scenario,)

    @property
    def effective_dispatch(self) -> str:
        """Resolve "auto": grouped pays off exactly when the roster is mixed."""
        if self.dispatch == "auto":
            return "grouped" if len(self.scenarios) > 1 else "switch"
        return self.dispatch


class SweepState(NamedTuple):
    """Checkpointable sweep state. All arrays have a leading [N] axis.

    The leading axis is always in LOGICAL instance order: the planner's
    gather/scatter repacking is confined to the inside of ``run_chunk``, so
    checkpoints, failure masks, and aggregation never see physical rows.
    """

    sim: SimState          # stacked per-instance simulator states
    metrics: SimMetrics    # stacked per-instance accumulators
    params: ScenarioParams # stacked per-instance scenario draws
    horizon: jax.Array     # [N] i32
    done: jax.Array        # [N] bool — the completion bitmap
    chunk: jax.Array       # [] i32 — walltime slices executed
    scenario_id: jax.Array # [N] i32 — index into SweepConfig.scenarios
    # recorded time series ([N]-stacked TraceBuffer) when
    # SweepConfig.record is set, else None (an empty pytree subtree, so
    # every tree.map/checkpoint/revert path handles both transparently)
    trace: TraceBuffer | None = None


@dataclass(frozen=True)
class GroupPlan:
    """One dense batch of a chunk execution plan.

    ``take[:keep]`` are the logical ids whose results are kept; rows past
    ``keep`` are padding (already-done instances when any exist — their
    rollout is a horizon-masked no-op and the results are discarded).
    """

    roster: int        # index into SweepConfig.scenarios; -1 = mixed (switch)
    take: np.ndarray   # [P] logical ids to gather, padded to worker multiple
    keep: int          # number of real (non-padding) rows
    identity: bool     # take == arange(N): gather/scatter can be skipped


def _partition_live(
    done: np.ndarray,
    scenario_ids: np.ndarray,
    *,
    grouped: bool,
    compaction: bool,
    hold: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray, list[tuple[int, np.ndarray]]]:
    """Shared first stage of BOTH planners (single-device group plans and
    multi-device block plans — they must never diverge, the bit-for-bit
    equivalence claims rest on it): the live set (pending instances under
    compaction, everyone otherwise), the done-pool padding source, and the
    per-roster ``(roster, ids)`` groups (one ``-1`` group when not
    grouped).

    ``hold`` (boolean [N]) excludes instances from the live set in EVERY
    mode, compaction or not — the fleet supervisor's retry-backoff and
    quarantine states (:mod:`repro.core.fleet`) ride on it, so a held
    instance is never stepped regardless of dispatch/compaction/sharding.
    Held instances are also never used as padding (padding must stay a
    masked no-op; only *done* instances qualify).
    """
    n = done.size
    mask_live = ~done if compaction else np.ones(n, bool)
    if hold is not None:
        mask_live = mask_live & ~hold
    live = np.flatnonzero(mask_live)
    pad_pool = np.flatnonzero(done)
    if grouped:
        rosters = np.unique(scenario_ids[live])
        groups = [(int(r), live[scenario_ids[live] == r]) for r in rosters]
    else:
        groups = [(-1, live)]
    return live, pad_pool, groups


def _pad_fill(pad_pool: np.ndarray, fallback: np.ndarray) -> np.ndarray:
    """The padding source both planners share: finished instances when any
    exist (so no live instance is stepped twice per chunk), else the given
    live fallback row — either way the padding rows' results are dropped
    by the keep-masked scatter."""
    return pad_pool if pad_pool.size else fallback


def _pad_group(idx: np.ndarray, pad_pool: np.ndarray, n_workers: int):
    """Pad ``idx`` to a multiple of the worker count (see :func:`_pad_fill`;
    the fallback row here is the group's own first live instance)."""
    pad = (-idx.size) % max(n_workers, 1)
    if pad == 0:
        return idx, idx.size
    fill = np.resize(_pad_fill(pad_pool, idx[:1]), pad)
    return np.concatenate([idx, fill]), idx.size


def plan_chunk(
    done: np.ndarray,
    scenario_ids: np.ndarray,
    n_workers: int,
    *,
    grouped: bool,
    compaction: bool,
    hold: np.ndarray | None = None,
) -> list[GroupPlan]:
    """Build the host-side execution plan for one chunk.

    Unifies straggler compaction and scenario grouping: ``compaction``
    selects the live set (pending instances only vs. everyone), ``grouped``
    splits the live set into one dense batch per roster entry, ``hold``
    masks instances out of the schedule entirely (retry backoff /
    quarantine — see :func:`_partition_live`). Returns an empty plan when
    nothing is pending.
    """
    n = done.size
    live, pad_pool, groups = _partition_live(
        done, scenario_ids, grouped=grouped, compaction=compaction,
        hold=hold,
    )
    if live.size == 0:
        return []
    plans = []
    for roster, idx in groups:
        take, keep = _pad_group(idx, pad_pool, n_workers)
        identity = take.size == n and keep == n and np.array_equal(
            take, np.arange(n)
        )
        plans.append(GroupPlan(roster=roster, take=take, keep=keep,
                               identity=identity))
    return plans


@dataclass(frozen=True)
class BlockPlan:
    """A device-blocked chunk execution plan — ONE sharded call per chunk.

    Device ``d`` owns rows ``take[d*cap : (d+1)*cap]`` of the gathered
    batch. ``keep`` marks the rows whose results are scattered back to
    their logical slots (padding rows — already-done instances, or a
    repeated live row when nothing has finished yet — are dropped).
    ``block_sid[d]`` is the roster index every row of device ``d``'s block
    runs (the per-device scalar ``lax.switch`` selector), or ``-1`` for a
    mixed block that falls back to the per-row vmapped switch.
    """

    take: np.ndarray       # [D*cap] logical ids (gather order)
    keep: np.ndarray       # [D*cap] bool — True where results are kept
    block_sid: np.ndarray  # [D] i32 — roster id per device block; -1 = mixed
    cap: int               # rows per device (multiple of workers_per_device)
    identity: bool         # take == arange(N), all kept: skip gather/scatter

    @property
    def n_devices(self) -> int:
        return self.block_sid.size


def plan_chunk_blocks(
    done: np.ndarray,
    scenario_ids: np.ndarray,
    n_devices: int,
    workers_per_device: int = 1,
    *,
    grouped: bool,
    compaction: bool,
    hold: np.ndarray | None = None,
) -> BlockPlan | None:
    """Pack one chunk's live instances into per-device-balanced blocks.

    The sharded analogue of :func:`plan_chunk` — instead of one global
    compaction (or one dense batch per scenario), the live set is packed
    onto ``n_devices`` device blocks by LPT, echoing the paper's node-level
    longest-job-first packing:

    1. partition live instances by scenario (when ``grouped``; otherwise a
       single roster ``-1`` group runs the vmapped-switch program),
    2. split any group larger than the fair share ``ceil(live / D)`` into
       fair-share-sized pieces (a group is split across devices ONLY when
       it cannot fit on one device — property-tested),
    3. LPT: place pieces largest-first onto the least-loaded device,
    4. ``cap`` = max device load rounded up to a ``workers_per_device``
       multiple; every block is padded to ``cap`` with already-done
       instances (whose rollout is a masked no-op and whose results are
       dropped), falling back to repeating a live row before anything has
       finished.

    A device block whose kept rows all share one scenario gets that
    roster's ``block_sid`` (scalar-switch dispatch: the device executes
    exactly one scenario branch); blocks forced to mix get ``-1`` (per-row
    vmapped switch for that block only). Returns ``None`` when nothing is
    pending. Deterministic: ties are broken by device index and roster id,
    so the same bitmap always produces the same plan.
    """
    n = done.size
    live, pad_pool, groups = _partition_live(
        done, scenario_ids, grouped=grouped, compaction=compaction,
        hold=hold,
    )
    if live.size == 0:
        return None
    d_count = max(n_devices, 1)
    wpd = max(workers_per_device, 1)
    # fair share per device; pieces never exceed it, so LPT never needs to
    # split a piece and a group spans >1 device only when it must
    fair = -(-live.size // d_count)
    pieces: list[tuple[int, np.ndarray]] = []
    for roster, idx in groups:
        for s in range(0, idx.size, fair):
            pieces.append((roster, idx[s : s + fair]))
    pieces.sort(key=lambda p: (-p[1].size, p[0]))  # LPT order, deterministic
    loads = np.zeros(d_count, np.int64)
    bins: list[list[tuple[int, np.ndarray]]] = [[] for _ in range(d_count)]
    for roster, idx in pieces:
        d = int(np.argmin(loads))  # least-loaded; argmin = lowest index tie
        bins[d].append((roster, idx))
        loads[d] += idx.size
    cap = max(int(loads.max()), 1)
    cap = -(-cap // wpd) * wpd
    take = np.empty(d_count * cap, np.int64)
    keep = np.zeros(d_count * cap, bool)
    block_sid = np.zeros(d_count, np.int32)
    fill_src = _pad_fill(pad_pool, live[:1])
    for d in range(d_count):
        ids = (
            np.concatenate([idx for _, idx in bins[d]])
            if bins[d]
            else np.empty(0, np.int64)
        )
        rosters_d = {roster for roster, _ in bins[d]}
        if len(rosters_d) == 1:
            block_sid[d] = rosters_d.pop()  # may be -1 (switch program)
        elif len(rosters_d) > 1:
            block_sid[d] = -1               # mixed block: per-row switch
        # an all-padding block runs any branch: its rows are done
        # instances whose rollout no-ops and whose results are dropped
        pad = cap - ids.size
        row = np.concatenate([ids, np.resize(fill_src, pad)]) if pad else ids
        take[d * cap : (d + 1) * cap] = row
        keep[d * cap : d * cap + ids.size] = True
    identity = bool(
        take.size == n and keep.all() and np.array_equal(take, np.arange(n))
    )
    return BlockPlan(take=take, keep=keep, block_sid=block_sid, cap=cap,
                     identity=identity)


def instance_sharding(mesh: Mesh | None):
    """The canonical sweep sharding: instance axis split over every mesh
    axis (``PartitionSpec(mesh.axis_names)``), everything else replicated.
    ``None`` mesh → ``None`` (single-device default placement)."""
    if mesh is None:
        return None
    return NamedSharding(mesh, P(mesh.axis_names))  # instance axis over all


_instance_sharding = instance_sharding  # back-compat alias


class SweepRunner:
    """Drives a sweep to 100 % completion in walltime-slice chunks.

    ``mesh`` (a 1-D device mesh, see :func:`repro.launch.mesh.make_host_mesh`)
    turns on the device-sharded executor: with D > 1 devices every chunk is
    ONE ``shard_map`` call over LPT-packed per-device blocks (module
    docstring). ``workers_per_device`` is the block-size granularity — the
    launcher's ``--workers`` flag: each device's block is padded to a
    multiple of it, and the fault injector's worker count is
    ``D * workers_per_device`` (the paper's nodes × instances-per-node).
    """

    def __init__(
        self,
        cfg: SweepConfig,
        mesh: Mesh | None = None,
        workers_per_device: int = 1,
    ) -> None:
        if cfg.dispatch not in DISPATCH_MODES:
            raise ValueError(
                f"dispatch must be one of {DISPATCH_MODES}, got {cfg.dispatch!r}"
            )
        if workers_per_device < 1:
            raise ValueError(
                f"workers_per_device must be >= 1, got {workers_per_device}"
            )
        self.cfg = cfg
        self.mesh = mesh
        self.sharding = instance_sharding(mesh)
        self.workers_per_device = workers_per_device
        self.n_devices = len(mesh.devices.flat) if mesh is not None else 1
        self.dispatch = cfg.effective_dispatch
        # one SimConfig per roster entry; every branch shares shapes, so the
        # switch path compiles a mixed sweep into a single SPMD program
        self._sims = tuple(
            dataclasses.replace(cfg.sim, scenario=s) for s in cfg.scenarios
        )
        # every chunk fn threads the trace (None when recording is off); the
        # RecordConfig is shared by all roster entries so lax.switch branches
        # return identical trees
        rec = cfg.record
        if len(self._sims) == 1:
            sim0 = self._sims[0]

            def chunk_one(st, m, sp, h, tr, sid):
                return rollout_chunk_rec(
                    st, m, sp, h, tr, sim0, cfg.chunk_steps, rec
                )
        else:
            branches = tuple(
                functools.partial(rollout_chunk_rec, cfg=s,
                                  n_steps=cfg.chunk_steps, rec=rec)
                for s in self._sims
            )

            def chunk_one(st, m, sp, h, tr, sid):
                return jax.lax.switch(sid, branches, st, m, sp, h, tr)

        self._chunk_fn = jax.jit(jax.vmap(chunk_one))
        # per-roster switch-free chunk fns for grouped dispatch, deduped by
        # SimConfig so a weighted mix (same scenario listed twice) shares one
        # compile cache entry; jit itself caches across chunks per shape
        by_sim: dict[SimConfig, Callable] = {}
        for s in self._sims:
            if s not in by_sim:
                by_sim[s] = jax.jit(jax.vmap(functools.partial(
                    rollout_chunk_rec, cfg=s, n_steps=cfg.chunk_steps, rec=rec
                )))
        self._roster_fns = tuple(by_sim[s] for s in self._sims)
        if self.n_devices > 1:
            self._build_block_fns()

    def _build_block_fns(self) -> None:
        """The D>1 executors: one ``shard_map`` program per chunk.

        Two jitted variants, compiled lazily on first use:

        - ``_block_fn_uniform`` — every device block is single-scenario:
          a per-device *scalar* ``lax.switch`` (an HLO conditional — the
          device executes only its own scenario's rollout at runtime).
        - ``_block_fn_full`` — adds the mixed-block fallback: a scalar
          ``lax.cond`` picks between the scalar switch and a per-row
          vmapped switch, so a ``block_sid = -1`` block pays the k× switch
          tax while uniform blocks on other devices don't. Only used for
          plans that actually contain a mixed block.
        """
        cfg, rec, sims = self.cfg, self.cfg.record, self._sims
        mesh = self.mesh
        branch_fns = [
            jax.vmap(functools.partial(
                rollout_chunk_rec, cfg=s, n_steps=cfg.chunk_steps, rec=rec
            ))
            for s in sims
        ]
        row_branches = tuple(
            functools.partial(rollout_chunk_rec, cfg=s,
                              n_steps=cfg.chunk_steps, rec=rec)
            for s in sims
        )

        def uniform(ops, block_sid):
            if len(branch_fns) == 1:
                return branch_fns[0](*ops)
            return jax.lax.switch(jnp.maximum(block_sid, 0), branch_fns, *ops)

        def mixed(ops, row_sid):
            st, m, sp, h, tr = ops
            return jax.vmap(
                lambda st, m, sp, h, tr, sid: jax.lax.switch(
                    sid, row_branches, st, m, sp, h, tr
                )
            )(st, m, sp, h, tr, row_sid)

        def block_uniform(st, m, sp, h, tr, row_sid, block_sid):
            return uniform((st, m, sp, h, tr), block_sid[0])

        def block_full(st, m, sp, h, tr, row_sid, block_sid):
            ops = (st, m, sp, h, tr)
            return jax.lax.cond(
                block_sid[0] >= 0,
                lambda o: uniform(o, block_sid[0]),
                lambda o: mixed(o, row_sid),
                ops,
            )

        from jax.experimental.shard_map import shard_map

        spec = P(mesh.axis_names)
        wrap = lambda f: jax.jit(shard_map(  # noqa: E731
            f, mesh=mesh, in_specs=spec, out_specs=spec
        ))
        self._block_fn_uniform = wrap(block_uniform)
        self._block_fn_full = (
            wrap(block_full) if len(sims) > 1 else self._block_fn_uniform
        )

    # ---------------- init ----------------

    def init(self) -> SweepState:
        cfg = self.cfg
        sims = self._sims
        base = jax.random.key(cfg.seed)

        def init_one(i):
            k = jax.random.fold_in(base, i)
            sid = jnp.asarray(i % len(sims), jnp.int32)
            k_sp = jax.random.fold_in(k, 1)
            if len(sims) == 1:
                sp = get_scenario(sims[0].scenario).sample_params(k_sp, sims[0])
            else:
                sp = jax.lax.switch(
                    sid,
                    tuple(
                        functools.partial(get_scenario(s.scenario).sample_params,
                                          cfg=s)
                        for s in sims
                    ),
                    k_sp,
                )
            st = init_state(cfg.sim, jax.random.fold_in(k, 2))
            if cfg.vary_horizon:
                frac = jax.random.uniform(
                    jax.random.fold_in(k, 3), (),
                    minval=cfg.min_horizon_frac, maxval=1.0,
                )
                horizon = (frac * cfg.steps_per_instance).astype(jnp.int32)
            else:
                horizon = jnp.asarray(cfg.steps_per_instance, jnp.int32)
            return st, SimMetrics.zeros(), sp, horizon, sid

        ids = jnp.arange(cfg.n_instances)
        sim, metrics, params, horizon, sids = jax.jit(jax.vmap(init_one))(ids)
        trace = (
            batch_zeros(cfg.record, cfg.steps_per_instance, cfg.n_instances)
            if cfg.record is not None
            else None
        )
        state = SweepState(
            sim=sim,
            metrics=metrics,
            params=params,
            horizon=horizon,
            done=jnp.zeros((cfg.n_instances,), bool),
            chunk=jnp.zeros((), jnp.int32),
            scenario_id=sids,
            trace=trace,
        )
        return self._place(state)

    def _place(self, state: SweepState) -> SweepState:
        """Shard the resting [N] state over the mesh when N divides evenly.

        Otherwise the logical-order state stays on default placement — the
        per-chunk gathered batch (always ``D*cap`` rows) is what actually
        gets sharded for compute (:meth:`_run_block`), so an indivisible
        instance count costs one extra host-side repack, never correctness.
        """
        if self.sharding is None or self.cfg.n_instances % self.n_devices:
            return state
        shard = self.sharding

        def put(x):
            if getattr(x, "ndim", 0) >= 1 and x.shape[0] == self.cfg.n_instances:
                return jax.device_put(x, shard)
            return x

        return jax.tree.map(put, state)

    def _n_workers(self) -> int:
        """Total worker slots: mesh devices × per-device instances.

        The fault injector and the planner's padding granularity both key
        on this — the paper's ``nodes × instances-per-node`` (6 × 8 = 48).
        """
        return self.n_devices * self.workers_per_device

    # ---------------- one walltime slice ----------------

    def _host_bitmap(self, state: SweepState) -> tuple[np.ndarray, np.ndarray]:
        """Pull (done, scenario_id) to host and validate the assignment.

        The planner partitions on the state's own assignment (not an
        assumed round-robin) so grouped dispatch honors whatever
        scenario_id a restored or hand-built state carries, like the
        switch program does — except that lax.switch silently clamps
        out-of-range ids; here that would mean stepping an instance with
        the wrong scenario's physics, so reject it loudly (it only happens
        on config drift at restore time).
        """
        done, sids = jax.device_get((state.done, state.scenario_id))
        done, sids = np.asarray(done), np.asarray(sids)
        if sids.size and (sids.min() < 0 or sids.max() >= len(self._sims)):
            raise ValueError(
                f"state.scenario_id out of range for a {len(self._sims)}-"
                f"entry roster {self.cfg.scenarios} — was this state "
                "restored from a sweep with a different scenario_mix?"
            )
        return done, sids

    def plan_chunk(
        self, state: SweepState, hold: np.ndarray | None = None
    ) -> list[GroupPlan]:
        """The (single-device) chunk execution plan for the current bitmap."""
        cfg = self.cfg
        grouped = self.dispatch == "grouped"
        no_hold = hold is None or not hold.any()
        if not cfg.compaction and not grouped and no_hold:
            # full-width switch program: no repacking needed
            n = cfg.n_instances
            return [GroupPlan(roster=-1, take=np.arange(n), keep=n,
                              identity=True)]
        done, sids = self._host_bitmap(state)
        return plan_chunk(done, sids, self._n_workers(),
                          grouped=grouped, compaction=cfg.compaction,
                          hold=hold)

    def plan_chunk_sharded(
        self, state: SweepState, hold: np.ndarray | None = None
    ) -> BlockPlan | None:
        """The D>1 plan: per-device LPT blocks (:func:`plan_chunk_blocks`)."""
        done, sids = self._host_bitmap(state)
        return plan_chunk_blocks(
            done, sids, self.n_devices, self.workers_per_device,
            grouped=self.dispatch == "grouped",
            compaction=self.cfg.compaction,
            hold=hold,
        )

    def run_chunk(
        self, state: SweepState, hold: np.ndarray | None = None
    ) -> SweepState:
        """Advance every pending instance by one walltime slice.

        Dispatch is asynchronous: the returned state's arrays are futures
        the devices are still computing — callers only block when they
        read them (``jax.device_get`` / ``block_until_ready``), which is
        what the pipelined run loop exploits to overlap host I/O with
        device compute (:func:`repro.core.fault.run_with_failures`).

        ``hold`` (boolean [N]) keeps the masked instances off this chunk's
        schedule — their state is untouched and the chunk counter still
        advances, which is how the fleet supervisor implements retry
        backoff and quarantine (:mod:`repro.core.fleet`). A chunk whose
        live set is empty (everything done, quarantined or held) is a
        counter-only no-op.
        """
        if self.n_devices > 1:
            bp = self.plan_chunk_sharded(state, hold)
            if bp is not None:
                state = self._run_block(state, bp)
        else:
            for plan in self.plan_chunk(state, hold):
                state = self._run_group(state, plan)
        done = state.sim.t >= state.horizon
        return state._replace(done=done, chunk=state.chunk + 1)

    def _run_block(self, state: SweepState, bp: BlockPlan) -> SweepState:
        """Gather per-device blocks, run ONE sharded call, scatter back.

        The gather + explicit ``device_put`` onto the instance sharding is
        the chunk's only data movement; inside the ``shard_map`` call each
        device steps its own rows with zero collectives.
        """
        take = jnp.asarray(bp.take)
        if bp.identity:
            sub = (state.sim, state.metrics, state.params, state.horizon,
                   state.trace)
            row_sid = state.scenario_id
        else:
            sub = jax.tree.map(
                lambda x: x[take],
                (state.sim, state.metrics, state.params, state.horizon,
                 state.trace),
            )
            row_sid = state.scenario_id[take]
        sub = jax.device_put(sub, self.sharding)
        row_sid = jax.device_put(row_sid, self.sharding)
        bsid = jax.device_put(jnp.asarray(bp.block_sid), self.sharding)
        fn = (
            self._block_fn_full
            if (bp.block_sid < 0).any()
            else self._block_fn_uniform
        )
        sim, metrics, trace = fn(*sub, row_sid, bsid)
        if bp.identity:
            return state._replace(sim=sim, metrics=metrics, trace=trace)
        kept = jnp.asarray(np.flatnonzero(bp.keep))
        upd = jnp.asarray(bp.take[bp.keep])

        def scatter(full, part):
            return full.at[upd].set(part[kept])

        return state._replace(
            sim=jax.tree.map(scatter, state.sim, sim),
            metrics=jax.tree.map(scatter, state.metrics, metrics),
            trace=jax.tree.map(scatter, state.trace, trace),
        )

    def _run_group(self, state: SweepState, plan: GroupPlan) -> SweepState:
        """Gather one plan group, step it, scatter results to logical slots.

        The trace buffer rides the same gather/scatter as sim/metrics
        (``state.trace`` is None when recording is off — an empty subtree
        every tree.map here passes through untouched), which is what makes
        recording dispatch-agnostic by construction.
        """
        fn = self._chunk_fn if plan.roster < 0 else self._roster_fns[plan.roster]
        if plan.identity:
            args = (state.sim, state.metrics, state.params, state.horizon,
                    state.trace)
            sim, metrics, trace = (
                fn(*args, state.scenario_id) if plan.roster < 0 else fn(*args)
            )
            return state._replace(sim=sim, metrics=metrics, trace=trace)
        take = jnp.asarray(plan.take)
        sub = jax.tree.map(
            lambda x: x[take],
            (state.sim, state.metrics, state.params, state.horizon,
             state.trace),
        )
        if plan.roster < 0:
            sim, metrics, trace = self._chunk_fn(*sub, state.scenario_id[take])
        else:
            sim, metrics, trace = fn(*sub)
        # drop padding rows, scatter results back to logical slots
        keep = plan.keep
        upd = jnp.asarray(plan.take[:keep])

        def scatter(full, part):
            return full.at[upd].set(part[:keep])

        return state._replace(
            sim=jax.tree.map(scatter, state.sim, sim),
            metrics=jax.tree.map(scatter, state.metrics, metrics),
            trace=jax.tree.map(scatter, state.trace, trace),
        )

    # ---------------- full run with fault handling ----------------

    def run(
        self,
        state: SweepState | None = None,
        max_chunks: int = 10_000,
        on_chunk: Callable[[int, SweepState], SweepState] | None = None,
    ) -> SweepState:
        """Run until the completion bitmap is all-true.

        ``on_chunk(chunk_idx, state) -> state`` is the fault-injection /
        checkpoint hook: it may revert instances (simulated node failure) or
        persist state. The loop re-schedules whatever remains incomplete —
        completion always reaches 100 % (paper §5.2).
        """
        if state is None:
            state = self.init()
        for c in range(max_chunks):
            if bool(jax.device_get(jnp.all(state.done))):
                break
            state = self.run_chunk(state)
            if on_chunk is not None:
                state = on_chunk(c, state)
        return state

    # ---------------- elastic re-meshing ----------------

    def remesh(self, state: SweepState, mesh: Mesh | None) -> SweepState:
        """Move a sweep onto a different mesh (elastic scale up/down).

        Logical state is untouched — only placement and the block
        executors change — so a checkpoint taken on N devices resumes on
        M devices bit-for-bit (tests/test_sharded.py).
        """
        self.mesh = mesh
        self.sharding = instance_sharding(mesh)
        self.n_devices = len(mesh.devices.flat) if mesh is not None else 1
        if self.n_devices > 1:
            self._build_block_fns()
        return self._place(state)


def completion_rate(state: SweepState) -> float:
    return float(jax.device_get(jnp.mean(state.done.astype(jnp.float32))))
