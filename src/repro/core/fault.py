"""Fault tolerance for sweeps: failure injection, revert, checkpoint hooks.

The paper reports a 100 % simulation completion rate over 12 hours (§5.2) —
PBS re-queues failed array elements. Here failures are *injected* (a worker's
chunk results are discarded, as if the node died mid-slice) and the sweep loop
re-schedules the affected instances from their last durable state; tests
assert the completion bitmap still reaches 100 %.

Failure masks and reverts operate on LOGICAL instance ids. The sweep's chunk
execution planner (compaction + scenario grouping, ``repro.core.sweep``)
repacks instances onto physical rows inside ``run_chunk``, but every
``SweepState`` it returns is back in logical order — so this module is
dispatch- AND sharding-agnostic by construction: the same failure plan
kills the same instances under ``switch`` and ``grouped`` dispatch, with
or without compaction, on one device or on an N-device mesh (the
device-blocked executor's LPT packing is just another physical-row
permutation the masks never see), and trajectories stay bit-for-bit
identical across all of it (tests/test_fault.py, tests/test_sharded.py).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.core.sweep import SweepState, SweepRunner


@dataclasses.dataclass
class FailureInjector:
    """Deterministically kills worker shards at configured chunk indices.

    ``plan`` maps chunk index → list of worker ids that fail during that
    chunk. A failed worker loses the chunk's progress for every instance it
    was carrying (its shard of the instance axis).
    """

    n_workers: int
    plan: dict[int, list[int]]

    def failed_workers(self, chunk: int) -> list[int]:
        return self.plan.get(chunk, [])

    def instance_mask(self, chunk: int, n_instances: int) -> np.ndarray:
        """Boolean [N] over LOGICAL instance ids: True where the carrying
        worker failed this chunk.

        The worker→instance map is the static ceil-block assignment, NOT the
        planner's per-chunk physical packing — deliberately, so the failure
        model (and therefore the trajectory) is independent of dispatch
        mode, compaction, AND device sharding: ``n_workers`` is the logical
        ``devices × workers_per_device`` grid, not whatever LPT block an
        instance happened to land on this chunk."""
        mask = np.zeros((n_instances,), bool)
        per = -(-n_instances // self.n_workers)  # ceil block size
        for w in self.failed_workers(chunk):
            mask[w * per : (w + 1) * per] = True
        return mask

    @staticmethod
    def random(
        n_workers: int, n_chunks: int, fail_prob: float, seed: int = 0
    ) -> "FailureInjector":
        rng = np.random.default_rng(seed)
        plan: dict[int, list[int]] = {}
        for c in range(n_chunks):
            dead = [w for w in range(n_workers) if rng.random() < fail_prob]
            if dead:
                plan[c] = dead
        return FailureInjector(n_workers, plan)


# the simulator fault taxonomy (Afzal et al. 2020 catalog crashes, hangs
# and nondeterministic stragglers as the dominant robotics-sim failure
# modes; corrupted durable writes are the storage-layer analogue)
FAULT_KINDS = ("crash", "hang", "straggler", "corrupt_ckpt", "corrupt_shard")


@dataclasses.dataclass
class FaultModel(FailureInjector):
    """Full fault taxonomy for unattended runs — the chaos test harness.

    Extends the crash-only :class:`FailureInjector` (``plan`` stays the
    worker-crash schedule) with every failure mode the fleet supervisor
    (:mod:`repro.core.fleet`) must degrade gracefully under:

    - ``hangs``: chunk → workers that exceed the per-chunk deadline. The
      supervisor times them out and reverts their instances — same state
      effect as a crash, distinct journal event (and, in the process
      controller, a real heartbeat-loss SIGKILL).
    - ``stragglers``: chunk → workers that run slow but finish within
      deadline. Graceful path: results are KEPT, the event is journaled
      (the paper's straggler mitigation is compaction, not re-execution).
    - ``poison_instances``: logical instance ids that kill their worker
      *every* chunk they are scheduled — the retry-budget/quarantine
      stressor. Only the poison instance itself is reverted and charged,
      so quarantining it frees the rest of the fleet.
    - ``corrupt_ckpt`` / ``corrupt_shard``: chunk indices after whose
      checkpoint save / shard drain the newest durable artifact is
      truncated on disk — exercising digest-validated restore fallback
      and the dataset writer's shard re-scan.

    All channels address the same static ``devices × workers_per_device``
    grid as the base class, so the taxonomy stays dispatch-, compaction-
    and sharding-agnostic.
    """

    hangs: dict[int, list[int]] = dataclasses.field(default_factory=dict)
    stragglers: dict[int, list[int]] = dataclasses.field(default_factory=dict)
    poison_instances: tuple[int, ...] = ()
    corrupt_ckpt: frozenset = frozenset()
    corrupt_shard: frozenset = frozenset()

    def lost_workers(self, chunk: int) -> list[tuple[str, int]]:
        """Workers whose chunk results are lost, with the fault kind —
        crashes plus hangs (a timed-out worker loses the slice exactly
        like a dead one; only the journal event differs)."""
        return [("crash", w) for w in self.plan.get(chunk, [])] + [
            ("hang", w) for w in self.hangs.get(chunk, [])
        ]

    def failed_workers(self, chunk: int) -> list[int]:
        """Back-compat surface for :func:`run_with_failures`: every worker
        whose slice is lost this chunk (crashes AND hangs)."""
        return [w for _, w in self.lost_workers(chunk)]

    def straggler_workers(self, chunk: int) -> list[int]:
        return self.stragglers.get(chunk, [])

    def worker_mask(self, worker: int, n_instances: int) -> np.ndarray:
        """Boolean [N] over LOGICAL ids carried by ``worker`` (static
        ceil-block assignment — see :meth:`instance_mask`)."""
        mask = np.zeros((n_instances,), bool)
        per = -(-n_instances // self.n_workers)
        mask[worker * per : (worker + 1) * per] = True
        return mask

    def worker_of(self, instance: int, n_instances: int) -> int:
        per = -(-n_instances // self.n_workers)
        return instance // per

    @staticmethod
    def random_model(
        n_workers: int,
        n_chunks: int,
        fail_prob: float,
        hang_prob: float = 0.0,
        straggler_prob: float = 0.0,
        poison_instances: tuple[int, ...] = (),
        corrupt_ckpt_prob: float = 0.0,
        corrupt_shard_prob: float = 0.0,
        seed: int = 0,
    ) -> "FaultModel":
        """A seeded random chaos schedule over every fault channel."""
        rng = np.random.default_rng(seed)
        crashes: dict[int, list[int]] = {}
        hangs: dict[int, list[int]] = {}
        slows: dict[int, list[int]] = {}
        bad_ckpt, bad_shard = set(), set()
        for c in range(n_chunks):
            for table, p in ((crashes, fail_prob), (hangs, hang_prob),
                             (slows, straggler_prob)):
                hit = [w for w in range(n_workers) if rng.random() < p]
                if hit:
                    table[c] = hit
            if rng.random() < corrupt_ckpt_prob:
                bad_ckpt.add(c)
            if rng.random() < corrupt_shard_prob:
                bad_shard.add(c)
        return FaultModel(
            n_workers, crashes, hangs=hangs, stragglers=slows,
            poison_instances=tuple(poison_instances),
            corrupt_ckpt=frozenset(bad_ckpt),
            corrupt_shard=frozenset(bad_shard),
        )


def revert_instances(
    state: SweepState, snapshot: SweepState, mask: np.ndarray
) -> SweepState:
    """Discard masked instances' progress, restoring them from ``snapshot``."""
    m = jnp.asarray(mask)

    def pick(cur, old):
        if getattr(cur, "ndim", 0) >= 1 and cur.shape[0] == m.shape[0]:
            bm = m.reshape((-1,) + (1,) * (cur.ndim - 1))
            return jnp.where(bm, old, cur)
        return cur

    reverted = jax.tree.map(pick, state, snapshot)
    # chunk counter is global, keep the current one
    return reverted._replace(chunk=state.chunk)


def run_with_failures(
    runner: SweepRunner,
    injector: FailureInjector,
    ckpt: CheckpointManager | None = None,
    state: SweepState | None = None,
    max_chunks: int = 10_000,
    on_progress: Callable[[int, float], None] | None = None,
    writer=None,
    pipeline: bool = False,
) -> tuple[SweepState, dict]:
    """Full fault-tolerant run loop.

    Per chunk: snapshot (durable state) → run chunk → inject failures
    (revert the killed workers' instances to the snapshot) → checkpoint →
    drain finished instances to ``writer`` (a
    :class:`repro.data.shards.DatasetWriter`, for recording sweeps). The
    drain runs strictly after failure injection, so a ``done`` bit can no
    longer be reverted once an instance is handed to the writer. Returns
    the final state plus bookkeeping (chunks run, failure events,
    completion rate — the paper's §5.2 numbers).

    ``pipeline=True`` double-buffers the host I/O against device compute:
    chunk dispatch is asynchronous (``run_chunk`` returns futures), so the
    loop dispatches chunk ``c`` first and only then performs chunk
    ``c-1``'s deferred checkpoint write and shard drain — npz compression,
    jsonl/manifest writes and the checkpoint's host copy all overlap the
    devices' chunk-``c`` compute. The drain's device-side gather is
    enqueued *before* chunk ``c`` is dispatched
    (:meth:`~repro.data.shards.DatasetWriter.begin_drain`), so it never
    queues behind a whole chunk on the device stream. The only
    synchronization point per chunk is the completion bitmap the planner
    needs anyway. Both modes produce bit-for-bit identical states, shards
    and checkpoints — pipelining reorders *when* files are written, never
    what is written (tests/test_sharded.py); a mid-run kill can at worst
    lose one chunk's checkpoint lag, which resume already tolerates.
    """
    if state is None:
        state = runner.init()
    if ckpt is not None and ckpt.has_checkpoint():
        state, meta = ckpt.restore(like=state)
        state = runner._place(state)
    events = []
    chunks_run = 0
    # deferred host I/O from the previous chunk: (chunk id, state, gather)
    deferred: tuple[int, SweepState, object] | None = None

    def flush(d) -> None:
        if d is None:
            return
        step, st, handle = d
        if ckpt is not None:
            ckpt.save(step, st)
        if writer is not None:
            writer.finish_drain(handle)

    for _ in range(max_chunks):
        if bool(jax.device_get(jnp.all(state.done))):
            break
        # index the fault plan by the ABSOLUTE chunk counter, not the loop
        # iteration: a resumed run restarts the loop at 0 but the schedule
        # addresses chunks since sweep start, so kill/resume parity for
        # faulted sweeps requires the restored counter (tests/test_fault.py)
        c = int(jax.device_get(state.chunk))
        snapshot = state
        state = runner.run_chunk(state)
        chunks_run += 1
        dead = injector.failed_workers(c)
        if dead:
            mask = injector.instance_mask(c, runner.cfg.n_instances)
            state = revert_instances(state, snapshot, mask)
            # recompute bitmap after revert
            state = state._replace(done=state.sim.t >= state.horizon)
            events.append({"chunk": c, "workers": dead,
                           "instances": int(mask.sum())})
        if pipeline:
            # chunk c is in flight on the devices; do chunk c-1's file I/O
            # now, while they compute
            flush(deferred)
            done_np = np.asarray(jax.device_get(state.done))  # sync point
            handle = (
                writer.begin_drain(state, done=done_np)
                if writer is not None
                else None
            )
            deferred = (int(jax.device_get(state.chunk)), state, handle)
        else:
            if ckpt is not None:
                ckpt.save(int(jax.device_get(state.chunk)), state)
            if writer is not None:
                writer.drain(state)
        if on_progress is not None:
            done = float(jax.device_get(jnp.mean(state.done.astype(jnp.float32))))
            on_progress(c, done)
    flush(deferred)
    if writer is not None:
        # the loop breaks BEFORE running a chunk when everything is already
        # done — e.g. resuming a finished sweep's checkpoint, or a kill that
        # landed between the final ckpt.save and its drain. Drain is
        # idempotent (persisted instances are skipped), so one final call
        # closes that window and keeps the no-dropped-rows guarantee.
        writer.drain(state)
    completion = float(
        jax.device_get(jnp.mean(state.done.astype(jnp.float32)))
    )
    return state, {
        "chunks_run": chunks_run,
        "failure_events": events,
        "completion_rate": completion,
    }
