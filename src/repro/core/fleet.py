"""Unattended-run fleet supervision: retry budgets, quarantine, journaling.

The paper's operational headline is a 100 % simulation completion rate
over 12-hour unattended runs (§5.2) — PBS re-queues whatever dies. This
module is the in-process half of that contract (the process half is
``repro.launch.controller``): a supervised run loop that survives the
full fault taxonomy of :class:`repro.core.fault.FaultModel` without a
human in the loop, and degrades gracefully instead of thrashing:

- **Retry budgets.** Every reverted instance is charged a retry;
  re-queueing backs off exponentially (:class:`RetryPolicy`, in chunk
  units via the planner's ``hold`` mask) so a flapping worker doesn't
  burn its budget in consecutive chunks.
- **Quarantine.** An instance that exhausts its budget is quarantined —
  permanently held, excluded from scheduling and from the *eligible*
  completion denominator. One poison instance degrades only itself; the
  rest of the fleet still reaches 100 % (the ``run_with_failures`` loop
  this supersedes would re-queue it forever).
- **Run journal.** Every event (chunk committed, failure, quarantine,
  shard repair, deadline overrun) is appended to a crash-safe jsonl log
  whose failure events carry the *post-update* retry counters and hold
  horizons — so a resumed supervisor rebuilds its fleet state by plain
  replay-as-assignment (:meth:`FleetState.replay`), no reconciliation.
- **Durable-state audit.** Each chunk's checkpoint save and shard drain
  are followed by integrity hooks: injected corruption
  (``FaultModel.corrupt_ckpt`` / ``corrupt_shard``) truncates the newest
  artifact on disk, and recovery is exercised live — checkpoint restore
  falls back past digest-mismatched steps, the dataset writer's
  :meth:`~repro.data.shards.DatasetWriter.verify_shards` detects and
  rewrites the damage.

:func:`completion_report` reproduces the paper's §5.2 completion-rate
accounting per scenario, with quarantine called out explicitly;
:func:`format_completion_table` renders it as the README table.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.ckpt.io import PAYLOAD, list_steps
from repro.core.fault import FailureInjector, FaultModel, revert_instances
from repro.core.sweep import SweepRunner, SweepState


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Per-instance retry budget and exponential re-queue backoff.

    ``max_retries`` is the budget: an instance whose failure count
    *exceeds* it is quarantined (so the default 3 allows three reverts
    and quarantines on the fourth). After failure number ``k`` the
    instance is held out of scheduling for ``backoff_chunks(k)`` chunks —
    ``backoff_base * backoff_factor**(k-1)``, capped at ``backoff_cap``
    so a long sweep never idles an instance indefinitely.
    """

    max_retries: int = 3
    backoff_base: int = 1
    backoff_factor: float = 2.0
    backoff_cap: int = 8

    def backoff_chunks(self, n_failures: int) -> int:
        """Hold duration (in chunks) after the ``n_failures``-th failure."""
        raw = self.backoff_base * self.backoff_factor ** max(n_failures - 1, 0)
        return int(min(self.backoff_cap, raw))


class RunJournal:
    """Append-only jsonl event log — the run's crash-safe flight recorder.

    Each :meth:`append` writes one JSON line and fsyncs, so the journal
    survives a SIGKILL mid-run with at most a torn final line (which
    :meth:`read` skips). Events that mutate fleet state ("failure",
    "quarantine") carry the post-update values, making replay plain
    assignment — see :meth:`FleetState.replay`.
    """

    def __init__(self, path: str) -> None:
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)

    def exists(self) -> bool:
        """True iff the journal file is present on disk."""
        return os.path.exists(self.path)

    def append(self, event: dict) -> None:
        """Durably append one event (adds a wall-clock ``time`` field)."""
        event = dict(event, time=time.time())
        with open(self.path, "a") as f:
            f.write(json.dumps(event) + "\n")
            f.flush()
            os.fsync(f.fileno())

    @staticmethod
    def read(path: str) -> list[dict]:
        """All parseable events, in append order. A torn line (kill
        mid-append) is skipped rather than poisoning the replay."""
        if not os.path.exists(path):
            return []
        events = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    events.append(json.loads(line))
                except json.JSONDecodeError:
                    continue
        return events


@dataclasses.dataclass
class FleetState:
    """Mutable per-instance supervision state (host-side, numpy).

    ``retries[i]`` counts charged failures, ``quarantined[i]`` marks a
    poison instance permanently removed from scheduling, and
    ``hold_until[i]`` is the first chunk index at which instance ``i``
    may run again (exponential backoff). Everything here is rebuilt from
    the journal on resume — it is deliberately NOT part of the jax
    checkpoint, so fleet bookkeeping never perturbs trajectory parity
    with an unsupervised run.
    """

    retries: np.ndarray      # [N] int64 — failures charged so far
    quarantined: np.ndarray  # [N] bool
    hold_until: np.ndarray   # [N] int64 — held while chunk < hold_until

    @staticmethod
    def zeros(n: int) -> "FleetState":
        """Fresh fleet state for ``n`` instances (no failures yet)."""
        return FleetState(
            retries=np.zeros(n, np.int64),
            quarantined=np.zeros(n, bool),
            hold_until=np.zeros(n, np.int64),
        )

    @staticmethod
    def replay(events: list[dict], n: int) -> "FleetState":
        """Rebuild fleet state from journal events by assignment.

        "failure" events carry post-update ``retries`` / ``hold_until``
        maps and "quarantine" events carry instance lists, so replay in
        append order converges to the exact state at the last fsync —
        the crash-safety contract of :class:`RunJournal`.
        """
        fs = FleetState.zeros(n)
        for e in events:
            kind = e.get("kind")
            if kind == "failure":
                for k, v in (e.get("retries") or {}).items():
                    fs.retries[int(k)] = int(v)
                for k, v in (e.get("hold_until") or {}).items():
                    fs.hold_until[int(k)] = int(v)
            elif kind == "quarantine":
                for i in e.get("instances", []):
                    fs.quarantined[int(i)] = True
        return fs

    def held(self, chunk: int) -> np.ndarray:
        """Boolean [N]: instances excluded from scheduling at ``chunk``
        (quarantined, or still inside their backoff window)."""
        return self.quarantined | (self.hold_until > chunk)


def _damage_checkpoint(root: str) -> int | None:
    """Truncate the newest checkpoint's payload in place (chaos hook).

    Returns the damaged step, or None when there is nothing to damage.
    The manifest's SHA-256 no longer matches, so restore must detect it
    and fall back — this is how ``FaultModel.corrupt_ckpt`` turns into a
    real on-disk fault.
    """
    steps = list_steps(root)
    if not steps:
        return None
    payload = os.path.join(root, f"step_{steps[-1]:09d}", PAYLOAD)
    try:
        size = os.path.getsize(payload)
        with open(payload, "r+b") as f:
            f.truncate(max(size // 2, 1))
    except OSError:
        return None
    return steps[-1]


def _damage_shard(root: str) -> int | None:
    """Truncate the newest committed shard npz in place (chaos hook).

    Returns the damaged shard index, or None. The writer's
    :meth:`~repro.data.shards.DatasetWriter.verify_shards` must detect
    the torn npz, drop the shard, and re-drain its instances.
    """
    import glob

    shards = sorted(glob.glob(os.path.join(root, "shard_*.npz")))
    if not shards:
        return None
    path = shards[-1]
    try:
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(size // 2, 1))
    except OSError:
        return None
    return int(os.path.basename(path)[len("shard_"):-len(".npz")])


def _as_model(faults: FailureInjector | None, n_workers: int) -> FaultModel:
    """Normalize any injector (or None) to a full FaultModel."""
    if faults is None:
        return FaultModel(n_workers, {})
    if isinstance(faults, FaultModel):
        return faults
    return FaultModel(faults.n_workers, faults.plan)


def run_supervised(
    runner: SweepRunner,
    faults: FailureInjector | None = None,
    policy: RetryPolicy | None = None,
    ckpt: CheckpointManager | None = None,
    writer=None,
    journal: RunJournal | None = None,
    state: SweepState | None = None,
    max_chunks: int = 10_000,
    on_progress: Callable[[int, float], None] | None = None,
    chunk_deadline: float | None = None,
    pipeline: bool = False,
) -> tuple[SweepState, dict]:
    """The supervised fault-tolerant run loop — §5.2 without a human.

    Supersedes :func:`repro.core.fault.run_with_failures` for unattended
    runs: same snapshot → run → revert → checkpoint → drain skeleton and
    the same bit-for-bit trajectory guarantees, plus retry budgets with
    exponential backoff, quarantine for poison instances, per-chunk
    durable-state audits and a replayable run journal.

    Per chunk (``c`` = absolute chunk counter, resume-safe):

    1. Terminate when every instance is done or quarantined.
    2. ``runner.run_chunk(state, hold=...)`` — quarantined and
       backing-off instances are planner-held (untouched, never padding).
    3. Inject faults: crashed/hung workers lose their live instances'
       progress (revert to snapshot); poison instances lose only their
       own. Each reverted instance is charged a retry, then either
       quarantined (budget exceeded) or held for ``backoff_chunks``.
       Stragglers keep their results and are only journaled.
    4. Durable writes: checkpoint save, shard drain, then the chaos
       corruption hooks and a :meth:`verify_shards` audit.
    5. Journal the chunk's events (failures first, chunk-commit last) and
       report progress via ``on_progress(c, done_frac)`` — AFTER the
       durable writes, so a kill right after a heartbeat always leaves a
       checkpoint at least as new as the heartbeat.

    ``chunk_deadline`` (seconds of wall clock per chunk) journals a
    "deadline" event on overrun — an in-process jax chunk cannot be
    preempted mid-flight, so genuine hangs are the process controller's
    job (heartbeat-loss SIGKILL, ``repro.launch.controller``); the
    deterministic hang fault (``FaultModel.hangs``) simulates the
    timeout + revert path in-process. ``pipeline=True`` keeps
    :func:`run_with_failures`' double-buffered host I/O: chunk ``c``'s
    durable writes, audits, journal events and heartbeat all happen
    while the devices compute chunk ``c+1``.

    Returns ``(state, info)`` where ``info`` carries ``chunks_run``,
    ``failure_events``, ``completion_rate`` (run_with_failures-compatible)
    plus ``eligible_completion_rate``, ``quarantined`` and the full
    :func:`completion_report`.
    """
    n = runner.cfg.n_instances
    faults = _as_model(faults, runner._n_workers())
    policy = policy or RetryPolicy()
    if state is None:
        state = runner.init()
    fleet = FleetState.zeros(n)
    resumed_events: list[dict] = []
    if journal is not None and journal.exists():
        resumed_events = RunJournal.read(journal.path)
        fleet = FleetState.replay(resumed_events, n)
    if ckpt is not None and ckpt.has_checkpoint():
        state, _meta = ckpt.restore(like=state)
        state = runner._place(state)
        if journal is not None:
            journal.append({
                "kind": "resume",
                "chunk": int(jax.device_get(state.chunk)),
                "skipped_ckpts": list(ckpt.last_skipped),
                "replayed_events": len(resumed_events),
            })

    def _emit(event: dict) -> None:
        if journal is not None:
            journal.append(event)

    chunks_run = 0
    failure_events: list[dict] = []
    # deferred host I/O from the previous chunk (pipeline mode):
    # (chunk id, post-chunk state, drain handle, journal events, done frac)
    deferred: tuple | None = None

    def _flush(packet) -> None:
        if packet is None:
            return
        c, st, handle, events, done_frac = packet
        if ckpt is not None:
            ckpt.save(c + 1, st)
            if c in faults.corrupt_ckpt:
                ckpt.wait()
                step = _damage_checkpoint(ckpt.root)
                events = events + [
                    {"kind": "corrupt_ckpt", "chunk": c, "step": step}
                ]
        if writer is not None:
            if handle is not None:
                writer.finish_drain(handle)
            else:
                writer.drain(st)
            if c in faults.corrupt_shard:
                idx = _damage_shard(writer.root)
                events = events + [
                    {"kind": "corrupt_shard", "chunk": c, "shard": idx}
                ]
            repaired = writer.verify_shards()
            if repaired:
                events = events + [
                    {"kind": "shard_repair", "chunk": c, "shards": repaired}
                ]
        for e in events:
            _emit(e)
        _emit({
            "kind": "chunk", "chunk": c, "done": done_frac,
            "quarantined": int(fleet.quarantined.sum()),
        })
        if on_progress is not None:
            on_progress(c, done_frac)

    for _ in range(max_chunks):
        done_host = np.asarray(jax.device_get(state.done))
        if np.all(done_host | fleet.quarantined):
            break
        # index fault plans and hold windows by the ABSOLUTE chunk counter
        # so a resumed run replays the same schedule (kill/resume parity)
        c = int(jax.device_get(state.chunk))
        held = fleet.held(c)
        alive = ~done_host & ~held
        snapshot = state
        t0 = time.monotonic()
        state = runner.run_chunk(state, hold=held if held.any() else None)
        chunks_run += 1

        # ---- fault injection: worker-granular crashes/hangs, then
        # instance-granular poison (only live instances are affected)
        events: list[dict] = []
        mask = np.zeros(n, bool)
        for kind, w in faults.lost_workers(c):
            wm = faults.worker_mask(w, n) & alive
            if wm.any():
                events.append({
                    "kind": "failure", "fault": kind, "chunk": c,
                    "workers": [w],
                    "instances": np.flatnonzero(wm).tolist(),
                })
                mask |= wm
        poison = np.zeros(n, bool)
        for i in faults.poison_instances:
            if 0 <= i < n and alive[i] and not mask[i]:
                poison[i] = True
        if poison.any():
            events.append({
                "kind": "failure", "fault": "poison", "chunk": c,
                "workers": None,
                "instances": np.flatnonzero(poison).tolist(),
            })
            mask |= poison
        slow = faults.straggler_workers(c)
        if slow:
            events.append({
                "kind": "straggler", "chunk": c, "workers": list(slow),
            })
        if mask.any():
            state = revert_instances(state, snapshot, mask)
            state = state._replace(done=state.sim.t >= state.horizon)
            ids = np.flatnonzero(mask)
            fleet.retries[ids] += 1
            over = ids[fleet.retries[ids] > policy.max_retries]
            back = ids[fleet.retries[ids] <= policy.max_retries]
            fleet.quarantined[over] = True
            for i in back:
                fleet.hold_until[i] = c + 1 + policy.backoff_chunks(
                    int(fleet.retries[i])
                )
            # failure events carry POST-update counters so journal replay
            # is plain assignment (FleetState.replay)
            for e in events:
                if e["kind"] != "failure":
                    continue
                e["retries"] = {
                    str(i): int(fleet.retries[i]) for i in e["instances"]
                }
                e["hold_until"] = {
                    str(i): int(fleet.hold_until[i]) for i in e["instances"]
                }
            if over.size:
                events.append({
                    "kind": "quarantine", "chunk": c,
                    "instances": over.tolist(),
                })
            failure_events.extend(
                {k: e[k] for k in ("chunk", "fault", "workers", "instances")}
                for e in events if e["kind"] == "failure"
            )

        if pipeline:
            # chunk c is in flight on the devices; commit chunk c-1's
            # durable state (and its journal/heartbeat) while they compute
            _flush(deferred)
        done_after = np.asarray(jax.device_get(state.done))  # sync point
        elapsed = time.monotonic() - t0
        if chunk_deadline is not None and elapsed > chunk_deadline:
            # an in-flight jax chunk can't be preempted: overruns degrade
            # gracefully to a journaled warning (real hangs are killed by
            # the process controller's heartbeat timeout)
            events.append({
                "kind": "deadline", "chunk": c,
                "elapsed": elapsed, "deadline": chunk_deadline,
            })
        done_frac = float(done_after.mean())
        handle = (
            writer.begin_drain(state, done=done_after)
            if (pipeline and writer is not None) else None
        )
        packet = (c, state, handle, events, done_frac)
        if pipeline:
            deferred = packet
        else:
            _flush(packet)

    _flush(deferred)
    if writer is not None:
        # idempotent close-out: anything a kill window or a shard repair
        # left unpersisted is re-drained here
        writer.drain(state)

    report = completion_report(state, fleet, runner.cfg.scenarios)
    info = {
        "chunks_run": chunks_run,
        "failure_events": failure_events,
        "completion_rate": report["total"]["completion_rate"],
        "eligible_completion_rate":
            report["total"]["eligible_completion_rate"],
        "quarantined": np.flatnonzero(fleet.quarantined).tolist(),
        "retries_total": int(fleet.retries.sum()),
        "report": report,
    }
    _emit({
        "kind": "complete",
        "chunks_run": chunks_run,
        "completion_rate": info["completion_rate"],
        "eligible_completion_rate": info["eligible_completion_rate"],
        "quarantined": info["quarantined"],
    })
    return state, info


def completion_report(
    state: SweepState,
    fleet: FleetState | None,
    scenarios: tuple[str, ...],
) -> dict:
    """The paper's §5.2 completion-rate accounting, per scenario.

    ``completion_rate`` counts ALL instances (a quarantined instance is a
    failure to complete — the honest headline number);
    ``eligible_completion_rate`` excludes quarantined instances (the
    fleet-health number: did everything we kept scheduling finish?). The
    supervisor's acceptance gate is eligible == 1.0 with every
    quarantined instance explicitly listed.
    """
    done = np.asarray(jax.device_get(state.done))
    sids = np.asarray(jax.device_get(state.scenario_id))
    n = done.size
    if fleet is None:
        fleet = FleetState.zeros(n)

    def _row(sel: np.ndarray, name: str) -> dict:
        total = int(sel.sum())
        completed = int((done & sel).sum())
        quar = int((fleet.quarantined & sel).sum())
        eligible = total - quar
        edone = int((done & sel & ~fleet.quarantined).sum())
        return {
            "scenario": name,
            "instances": total,
            "completed": completed,
            "completion_rate": completed / total if total else 1.0,
            "quarantined": quar,
            "eligible": eligible,
            "eligible_completion_rate":
                edone / eligible if eligible else 1.0,
            "retries": int(fleet.retries[sel].sum()),
        }

    rows = [
        _row(sids == i, name)
        for i, name in enumerate(scenarios)
        if bool((sids == i).any())
    ]
    return {"total": _row(np.ones(n, bool), "total"), "scenarios": rows}


def format_completion_table(report: dict) -> str:
    """Render :func:`completion_report` as the §5.2-style markdown table."""
    header = (
        "| Scenario | Instances | Completed | Completion | "
        "Quarantined | Eligible completion | Retries |\n"
        "|---|---|---|---|---|---|---|"
    )
    lines = [header]
    for row in report["scenarios"] + [report["total"]]:
        lines.append(
            "| {scenario} | {instances} | {completed} | {cr:.1%} | "
            "{quarantined} | {ecr:.1%} | {retries} |".format(
                cr=row["completion_rate"],
                ecr=row["eligible_completion_rate"],
                **{k: row[k] for k in (
                    "scenario", "instances", "completed", "quarantined",
                    "retries",
                )},
            )
        )
    return "\n".join(lines)
