"""Vectorized mixed-traffic simulator core — the Webots+SUMO analogue.

The paper runs a Webots front-end puppeteered by SUMO (§2.5.3) as its sample
workload: a mixed-traffic highway merge. Porting that to TPU means replacing
the process-per-instance binary simulator with a pure-JAX physics step:

- **IDM** (Intelligent Driver Model, Treiber et al. 2000) longitudinal
  car-following — what SUMO's default Krauss model approximates.
- **MOBIL** (Kesting et al. 2007) incentive/safety lane changing.
- **Pluggable scenarios**: everything workload-specific (road geometry,
  demand, the merge's gap acceptance, a lane-drop's forced exit, a ring
  road's wrap...) lives behind the Scenario API (``repro.core.scenarios``).
  ``sim_step`` itself is scenario-agnostic: it calls the scenario's three
  jit hook groups — ``longitudinal_mods``, ``lateral_rules``, ``boundary``
  — selected by the static ``SimConfig.scenario`` name, so new workloads
  never fork the physics step.

One instance = one row of a batched state pytree: ``vmap`` gives the paper's
"n simulation instances per node" and sharding the instance axis gives "across
n nodes" — both collapse into one SPMD program (DESIGN.md §2).

Shapes are static (fixed ``n_slots`` vehicle capacity, active-masking), so the
whole rollout jit-compiles into a single ``lax.scan``.

The neighbor search + IDM evaluation is the physics hot spot. All per-step
neighborhood queries (own-lane IDM, the four MOBIL candidate searches, the
ramp-merge target search, the post-lane-change recompute, and the
collision/TTC check — historically ~8 independent O(N²) scans) now route
through the **neighborhood engine** (``repro.core.neighbors``), selected by
``SimConfig.neighbor_impl``:

- ``"reference"`` — the original per-query masked all-pairs scans (parity
  oracle; slowest).
- ``"dense"``     — fused dense path: one ``[N,N]`` pairwise
  materialization per state snapshot, per-lane tables derived in a single
  batched reduction; every query becomes an O(N) gather.
- ``"sort"``      — O(N log N) (default): stable per-lane argsorts of
  positions per snapshot, queries answered by searchsorted adjacency.
  Fastest at every measured ``n_slots`` on CPU
  (see ``benchmarks/throughput.py``).
- ``"pallas"``    — the generalized multi-query TPU kernel
  (``repro.kernels.idm.neighbor_kernel``; interpret mode off-TPU).

``sim_step`` performs exactly **two** neighborhood constructions per step:
one for the pre-move snapshot (serving the own-lane, MOBIL and merge
queries via lane tables) and one for the post-lane-change snapshot (the
integration accel). The collision/TTC stage reuses the post-change lead
assignment with post-integration positions instead of running a third scan:
each vehicle is checked against the leader it was actually following during
the dt, which is equivalent up to within-step overtakes (< dt·Δv ≈ cm scale)
and preserves the crash-on-overlap invariant.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.scenario import (
    SimConfig,
    ScenarioParams,
    driver_params,
)
from repro.core.scenarios import get_scenario
from repro.core.scenarios.base import (  # noqa: F401  (idm_accel re-exported)
    RoadGeometry,
    Scenario,
    idm_accel,
)
from repro.core.neighbors import (  # noqa: F401  (neighbor_info re-exported)
    Neighbors,
    NeighborTables,
    build_tables,
    neighbor_info,
    query_lanes,
)

INF = 1e9


class SimState(NamedTuple):
    pos: jax.Array        # [N] f32, meters from segment start
    vel: jax.Array        # [N] f32, m/s
    lane: jax.Array       # [N] i32; n_lanes == ramp lane
    active: jax.Array     # [N] bool
    is_cav: jax.Array     # [N] bool
    v0: jax.Array         # [N] f32 desired speed
    T: jax.Array          # [N] f32 headway
    a_max: jax.Array      # [N] f32
    b_comf: jax.Array     # [N] f32
    s0: jax.Array         # [N] f32
    politeness: jax.Array # [N] f32
    cooldown: jax.Array   # [N] i32 lane-change cooldown
    key: jax.Array        # PRNG key
    t: jax.Array          # [] i32 step counter


class SimMetrics(NamedTuple):
    throughput: jax.Array      # [] i32 vehicles exited
    spawned: jax.Array         # [] i32
    speed_sum: jax.Array       # [] f32
    speed_count: jax.Array     # [] f32
    collisions: jax.Array      # [] i32
    merges_ok: jax.Array       # [] i32 scenario-forced lane moves (merges)
    ramp_blocked_steps: jax.Array  # [] i32 scenario congestion gauge
    # (field names keep their merge-era spelling: the struct must be
    # identical across scenarios for lax.switch sweeps; scenarios rename
    # them in records via Scenario.metric_aliases)
    lane_changes: jax.Array    # [] i32
    min_ttc: jax.Array         # [] f32
    steps: jax.Array           # [] i32

    @staticmethod
    def zeros() -> "SimMetrics":
        z_i = jnp.zeros((), jnp.int32)
        z_f = jnp.zeros((), jnp.float32)
        return SimMetrics(z_i, z_i, z_f, z_f, z_i, z_i, z_i, z_i,
                          jnp.asarray(INF, jnp.float32), z_i)


def init_state(cfg: SimConfig, key: jax.Array) -> SimState:
    """Empty world: every one of the ``cfg.n_slots`` vehicle slots inactive.

    Positions park at ``-INF`` meters (off-road sentinel), speeds at 0 m/s,
    driver parameters at their population means; ``key`` seeds the
    instance's in-sim PRNG stream (spawns, driver draws). The step counter
    ``t`` starts at 0 — horizons and trace-row indices are absolute step
    counts from here.
    """
    n = cfg.n_slots
    zf = jnp.zeros((n,), jnp.float32)
    return SimState(
        pos=zf - INF,
        vel=zf,
        lane=jnp.zeros((n,), jnp.int32),
        active=jnp.zeros((n,), bool),
        is_cav=jnp.zeros((n,), bool),
        v0=zf + 30.0,
        T=zf + 1.5,
        a_max=zf + 1.4,
        b_comf=zf + 2.0,
        s0=zf + 2.0,
        politeness=zf + 0.3,
        cooldown=jnp.zeros((n,), jnp.int32),
        key=key,
        t=jnp.zeros((), jnp.int32),
    )


# --------------------------------------------------------------------------
# physics primitives (idm_accel lives in scenarios.base; re-exported above)
# --------------------------------------------------------------------------

def _own_accel(st: SimState, cfg: SimConfig, geom: RoadGeometry,
               scn: Scenario, sp: ScenarioParams, query_lane, nb: Neighbors,
               ctx=None):
    """IDM accel of each vehicle against its lead in ``query_lane``, plus
    the scenario's extra longitudinal constraints (ramp wall, speed-limit
    zone, wrap-around leader, ...), clamped to ``[-b_max, a_max]``.
    ``ctx`` is the scenario's once-per-snapshot ``snapshot_ctx`` result."""
    v_lead = jnp.where(nb.has_lead, st.vel[nb.lead_idx], 0.0)
    gap = jnp.where(nb.has_lead, nb.lead_gap, INF)
    dv = jnp.where(nb.has_lead, st.vel - v_lead, 0.0)
    a = idm_accel(st.vel, dv, gap, st.v0, st.T, st.a_max, st.b_comf, st.s0)
    a = scn.longitudinal_mods(st, cfg, geom, sp, query_lane, nb, a, ctx)
    return jnp.clip(a, -cfg.b_max, st.a_max)


# --------------------------------------------------------------------------
# MOBIL lane changing (scenario gates eligibility + mandatory moves)
# --------------------------------------------------------------------------

def _mobil_candidate(st: SimState, cfg: SimConfig, geom: RoadGeometry,
                     scn: Scenario, sp: ScenarioParams, a_now,
                     own: Neighbors, tabs: NeighborTables, cand_lane,
                     ctx=None):
    """MOBIL incentive + safety for moving every vehicle to ``cand_lane[i]``.

    ``own`` is the current-lane neighborhood (lead for the old-follower
    gap, follower as MOBIL's vehicle k); ``tabs`` answers the candidate-lane
    query — no per-candidate O(N²) scans.
    """
    nb = tabs.query(cand_lane)
    li, lg, hl, fi, fg, hf = nb
    # self in target lane
    a_new = _own_accel(st, cfg, geom, scn, sp, cand_lane, nb, ctx)

    # new follower j: before = its current accel; after = following self
    a_j_before = jnp.where(hf, a_now[fi], 0.0)
    gap_j_after = jnp.where(hf, fg, INF)
    a_j_after = idm_accel(
        st.vel[fi], st.vel[fi] - st.vel, gap_j_after,
        st.v0[fi], st.T[fi], st.a_max[fi], st.b_comf[fi], st.s0[fi],
    )
    a_j_after = jnp.where(hf, a_j_after, 0.0)

    # old follower k: before = its current accel (following self);
    # after = following self's current lead
    ki, hk = own.foll_idx, own.has_foll
    lead_pos = jnp.where(own.has_lead, st.pos[own.lead_idx], INF)
    lead_vel = jnp.where(own.has_lead, st.vel[own.lead_idx], 0.0)
    gap_k_after = lead_pos[jnp.arange(st.pos.shape[0])] - st.pos[ki] - cfg.vehicle_len
    a_k_before = jnp.where(hk, a_now[ki], 0.0)
    a_k_after = idm_accel(
        st.vel[ki], st.vel[ki] - lead_vel, gap_k_after,
        st.v0[ki], st.T[ki], st.a_max[ki], st.b_comf[ki], st.s0[ki],
    )
    a_k_after = jnp.where(hk, a_k_after, 0.0)

    incentive = (a_new - a_now) + st.politeness * (
        (a_j_after - a_j_before) + (a_k_after - a_k_before)
    )
    safe = (a_j_after >= -cfg.b_safe) & (
        jnp.where(hf, fg, INF) > 0.0
    ) & (jnp.where(hl, lg, INF) > 0.0)
    return incentive, safe


def _apply_lane_changes(st: SimState, cfg: SimConfig, geom: RoadGeometry,
                        scn: Scenario, sp: ScenarioParams, a_now,
                        own: Neighbors, tabs: NeighborTables, ctx=None):
    """Simultaneous MOBIL decisions for scenario-eligible vehicles."""
    eligible = scn.mobil_eligible(st, cfg, geom) & st.active
    can_change = eligible & (st.cooldown == 0)

    left = jnp.minimum(st.lane + 1, geom.n_lanes - 1)
    right = jnp.maximum(st.lane - 1, 0)
    inc_l, safe_l = _mobil_candidate(st, cfg, geom, scn, sp, a_now, own,
                                     tabs, left, ctx)
    inc_r, safe_r = _mobil_candidate(st, cfg, geom, scn, sp, a_now, own,
                                     tabs, right, ctx)
    ok_l = (safe_l & (inc_l > cfg.mobil_athr) & (left != st.lane)
            & can_change & scn.mobil_candidate_ok(st, cfg, geom, left))
    ok_r = (safe_r & (inc_r > cfg.mobil_athr) & (right != st.lane)
            & can_change & scn.mobil_candidate_ok(st, cfg, geom, right))

    go_left = ok_l & (~ok_r | (inc_l >= inc_r))
    go_right = ok_r & ~go_left
    new_lane = jnp.where(go_left, left, jnp.where(go_right, right, st.lane))
    changed = go_left | go_right
    cooldown = jnp.where(
        changed, cfg.lane_change_cooldown, jnp.maximum(st.cooldown - 1, 0)
    )
    return new_lane, cooldown, jnp.sum(changed.astype(jnp.int32))


# --------------------------------------------------------------------------
# spawning — the demand process (per-instance randomized rates; the
# scenario's boundary_spawn hook decides WHICH lanes spawn at WHAT rates)
# --------------------------------------------------------------------------

def _spawn(st: SimState, cfg: SimConfig, geom: RoadGeometry, scn: Scenario,
           sp: ScenarioParams, key: jax.Array):
    """Bernoulli(λ·dt) arrivals per spawn lane; claims free slots with fresh
    drivers.

    Fully vectorized over the scenario's spawn lanes: one uniform block
    for every per-lane draw and a rank-based free-slot allocation, instead
    of the historical Python loop (~17 tiny PRNG/scatter ops per step —
    the dominant per-step cost at small ``n_slots``). At most one vehicle
    spawns per lane per step; each arriving lane claims the next-lowest
    free slot in lane order, exactly like the sequential loop did.
    """
    n = st.pos.shape[0]
    lam, base_v0, lanes = scn.boundary_spawn(cfg, geom, sp)
    n_spawn_lanes = lanes.shape[0]                   # static per scenario
    ku, kj = jax.random.split(key)
    u = jax.random.uniform(ku, (3, n_spawn_lanes))   # arrival, cav, v0 jitter

    arrive = u[0] < lam * cfg.dt                                   # [L]
    # headway check at the spawn point, all lanes at once
    in_lane = st.active[None, :] & (st.lane[None, :] == lanes[:, None])
    nearest = jnp.min(jnp.where(in_lane, st.pos[None, :], INF), axis=1)
    clear = nearest > cfg.spawn_gap
    if geom.ring:
        # on a closed road traffic also approaches the spawn point from
        # behind, across the seam, possibly at full speed — demand braking
        # headroom behind the seam before injecting a fresh vehicle
        rear_gap = geom.road_len - jnp.max(
            jnp.where(in_lane, st.pos[None, :], -INF), axis=1
        )
        clear = clear & (rear_gap > 3.0 * cfg.spawn_gap)

    # rank-based slot claim: the r-th lane that wants to spawn takes the
    # r-th-lowest free slot; lanes beyond the free-slot count miss out
    free = ~st.active
    n_free = jnp.sum(free.astype(jnp.int32))
    want = arrive & clear
    rank = jnp.cumsum(want.astype(jnp.int32)) - want.astype(jnp.int32)
    ok = want & (rank < n_free)
    free_slots = jnp.argsort(~free, stable=True)     # free indices first
    slot = jnp.where(ok, free_slots[jnp.minimum(rank, n - 1)], n)  # n = drop

    cav = u[1] < sp.p_cav
    new_v0 = base_v0 * (0.9 + 0.2 * u[2])
    dp = driver_params(cav, kj, n_spawn_lanes)
    # headway-derived entry speed uses the NEW driver's just-drawn headway
    # (the slot may still hold a previous occupant's stale T)
    init_v = jnp.minimum(new_v0, nearest / jnp.maximum(dp["T"], 0.5))

    def put(arr, val):
        return arr.at[slot].set(val.astype(arr.dtype), mode="drop")

    st = st._replace(
        pos=put(st.pos, jnp.zeros_like(new_v0)),
        vel=put(st.vel, jnp.maximum(init_v * 0.8, 5.0)),
        lane=put(st.lane, lanes),
        active=put(st.active, jnp.ones_like(cav)),
        is_cav=put(st.is_cav, cav),
        v0=put(st.v0, new_v0),
        T=put(st.T, dp["T"]),
        a_max=put(st.a_max, dp["a_max"]),
        b_comf=put(st.b_comf, dp["b_comf"]),
        s0=put(st.s0, dp["s0"]),
        politeness=put(st.politeness, dp["politeness"]),
    )
    return st, jnp.sum(ok.astype(jnp.int32))


# --------------------------------------------------------------------------
# one physics step
# --------------------------------------------------------------------------

def sim_step(
    st: SimState, cfg: SimConfig, sp: ScenarioParams
) -> tuple[SimState, SimMetrics]:
    """One dt step of ``cfg.scenario``. Returns the new state and this
    step's metric deltas. Scenario-specific physics enters only through the
    scenario's hooks — this function never special-cases a workload."""
    scn = get_scenario(cfg.scenario)
    geom = scn.geometry(cfg)
    key, k_spawn = jax.random.split(st.key)
    st = st._replace(key=key)
    impl = cfg.neighbor_impl
    n_lanes_total = geom.n_lanes_total

    # 1. pre-move snapshot: ONE fused neighborhood pass serves the own-lane
    #    accel, both MOBIL candidate evaluations and the scenario's
    #    lateral-rule queries (merge target, drop target, ...)
    tabs = build_tables(
        st.pos, st.lane, st.active, cfg.vehicle_len, n_lanes_total, impl
    )
    ctx = scn.snapshot_ctx(st, cfg, geom)
    own = tabs.query(st.lane)
    a_now = _own_accel(st, cfg, geom, scn, sp, st.lane, own, ctx)

    # 2. lane changes: discretionary MOBIL, then the scenario's mandatory
    #    moves (gap-acceptance merge, forced lane-drop exit, vetoes)
    new_lane, cooldown, n_lc = _apply_lane_changes(
        st, cfg, geom, scn, sp, a_now, own, tabs, ctx
    )
    new_lane, n_forced = scn.lateral_rules(st, cfg, geom, sp, tabs, new_lane)
    st = st._replace(lane=new_lane, cooldown=cooldown)

    # 3. post-change snapshot (second and last construction): recompute
    #    accel on post-change lanes, integrate, apply boundary clamps
    nb = query_lanes(
        st.pos, st.lane, st.active, cfg.vehicle_len, st.lane, impl,
        n_lanes_total=n_lanes_total,
    )
    ctx2 = scn.snapshot_ctx(st, cfg, geom)   # lanes changed: fresh snapshot
    accel = _own_accel(st, cfg, geom, scn, sp, st.lane, nb, ctx2)
    accel = jnp.where(st.active, accel, 0.0)
    vel = jnp.maximum(st.vel + accel * cfg.dt, 0.0)
    pos = st.pos + vel * cfg.dt
    pos, vel = scn.boundary_clamp(st, cfg, geom, pos, vel)
    st = st._replace(pos=pos, vel=vel)

    # 4. collisions: follower overlapping its lead → remove follower.
    #    Reuses the post-change lead assignment with the integrated
    #    positions (each vehicle vs the leader it followed during this dt)
    #    instead of a third all-pairs construction. On a ring the gap is
    #    measured with a centered wrap so a leader crossing the seam is
    #    not a phantom collision.
    li2, hl2 = nb.lead_idx, nb.has_lead
    dgap = st.pos[li2] - st.pos
    if geom.ring:
        half = 0.5 * geom.road_len
        dgap = jnp.mod(dgap + half, geom.road_len) - half
    lg2 = jnp.where(
        hl2, dgap - cfg.vehicle_len, INF - cfg.vehicle_len
    )
    crashed = st.active & hl2 & (lg2 < 0.0)
    n_crash = jnp.sum(crashed.astype(jnp.int32))

    # 5. exits (scenario predicate; a ring has none)
    exited = scn.boundary_exit(st, cfg, geom)
    n_out = jnp.sum(exited.astype(jnp.int32))
    active = st.active & ~exited & ~crashed
    st = st._replace(active=active, pos=jnp.where(active, st.pos, -INF))

    # 6. TTC (closing pairs only)
    dv = jnp.where(hl2, st.vel - st.vel[li2], 0.0)
    ttc = jnp.where(
        st.active & hl2 & (dv > 0.1), jnp.maximum(lg2, 0.0) / dv, INF
    )
    min_ttc = jnp.min(ttc)

    # 7. scenario congestion gauge (ramp blockage, drop blockage, stopped
    #    vehicles, zone occupancy — reported in the ramp_blocked_steps slot)
    n_blocked = scn.boundary_gauge(st, cfg, geom)

    # 8. demand (scenario decides spawn lanes/rates)
    st, n_spawn = _spawn(st, cfg, geom, scn, sp, k_spawn)
    st = st._replace(t=st.t + 1)

    delta = SimMetrics(
        throughput=n_out,
        spawned=n_spawn,
        speed_sum=jnp.sum(jnp.where(st.active, st.vel, 0.0)),
        speed_count=jnp.sum(st.active.astype(jnp.float32)),
        collisions=n_crash,
        merges_ok=n_forced,
        ramp_blocked_steps=n_blocked,
        lane_changes=n_lc,
        min_ttc=min_ttc,
        steps=jnp.ones((), jnp.int32),
    )
    return st, delta


def _acc(m: SimMetrics, d: SimMetrics) -> SimMetrics:
    return SimMetrics(
        throughput=m.throughput + d.throughput,
        spawned=m.spawned + d.spawned,
        speed_sum=m.speed_sum + d.speed_sum,
        speed_count=m.speed_count + d.speed_count,
        collisions=m.collisions + d.collisions,
        merges_ok=m.merges_ok + d.merges_ok,
        ramp_blocked_steps=m.ramp_blocked_steps + d.ramp_blocked_steps,
        lane_changes=m.lane_changes + d.lane_changes,
        min_ttc=jnp.minimum(m.min_ttc, d.min_ttc),
        steps=m.steps + d.steps,
    )


# --------------------------------------------------------------------------
# rollouts
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "n_steps", "rec"))
def rollout_chunk_rec(
    st: SimState,
    metrics: SimMetrics,
    sp: ScenarioParams,
    horizon: jax.Array,
    trace,
    cfg: SimConfig,
    n_steps: int,
    rec=None,
):
    """Advance ``n_steps`` (one walltime slice). Steps past ``horizon`` no-op.

    The per-instance ``horizon`` makes instances genuinely variable-cost —
    the straggler population the sweep scheduler must handle (DESIGN.md §7).

    With a :class:`repro.core.record.RecordConfig` ``rec`` (static), the
    rollout also fills ``trace`` (a :class:`repro.core.record.TraceBuffer`):
    rows are indexed by absolute step count, so recording is invariant to
    chunk boundaries and idempotent under re-execution (fault revert,
    checkpoint resume). With ``rec=None``, ``trace`` must be None and rides
    through untouched.

    Recording cost: when ``n_steps`` is a multiple of the stride, the scan
    is two-level — an outer scan over stride windows whose inner scan is
    the plain physics loop — so ALL recording work (channel extraction +
    buffer writes) runs once per window, not once per step. This relies on
    live instances entering a chunk at a stride-aligned step count, which
    every sweep path guarantees (``t`` only ever advances in whole chunks,
    and ``SweepConfig`` chunking makes chunk boundaries stride-aligned
    whenever this fast path is selected). Otherwise a per-step fallback
    records at identical bit-for-bit rows at ~1 extra write per step.
    """
    from repro.core.record import record_step  # deferred: no import cycle

    def step_body(carry, _):
        st, m = carry
        live = st.t < horizon
        st2, d = sim_step(st, cfg, sp)
        m2 = _acc(m, d)
        st = jax.tree.map(lambda a, b: jnp.where(live, b, a), st, st2)
        m = jax.tree.map(lambda a, b: jnp.where(live, b, a), m, m2)
        return (st, m), None

    if rec is None:
        (st, metrics), _ = jax.lax.scan(
            step_body, (st, metrics), None, length=n_steps
        )
        return st, metrics, trace

    stride = rec.record_every
    if n_steps % stride == 0:
        # fast path: record once per stride window (see docstring)
        def window(carry, _):
            st, m, tr = carry
            t0 = st.t
            (st, m), _ = jax.lax.scan(step_body, (st, m), None, length=stride)
            # an instance frozen at its horizon for the whole window must
            # not re-emit its final row every subsequent window
            tr = record_step(tr, st, m, rec, st.t > t0)
            return (st, m, tr), None

        (st, metrics, trace), _ = jax.lax.scan(
            window, (st, metrics, trace), None, length=n_steps // stride
        )
        return st, metrics, trace

    def body(carry, _):
        st, m, tr = carry
        live = st.t < horizon
        st2, d = sim_step(st, cfg, sp)
        m2 = _acc(m, d)
        # off-stride and not-live writes drop; live re-writes after a
        # revert reproduce identical rows (determinism)
        tr = record_step(tr, st2, m2, rec, live)
        st = jax.tree.map(lambda a, b: jnp.where(live, b, a), st, st2)
        m = jax.tree.map(lambda a, b: jnp.where(live, b, a), m, m2)
        return (st, m, tr), None

    (st, metrics, trace), _ = jax.lax.scan(
        body, (st, metrics, trace), None, length=n_steps
    )
    return st, metrics, trace


def rollout_chunk(
    st: SimState,
    metrics: SimMetrics,
    sp: ScenarioParams,
    horizon: jax.Array,
    cfg: SimConfig,
    n_steps: int,
) -> tuple[SimState, SimMetrics]:
    """Recording-free chunk rollout (see :func:`rollout_chunk_rec`)."""
    st, metrics, _ = rollout_chunk_rec(
        st, metrics, sp, horizon, None, cfg, n_steps, None
    )
    return st, metrics


def rollout(
    key: jax.Array, cfg: SimConfig, sp: ScenarioParams, n_steps: int
) -> SimMetrics:
    """Full single-instance episode from a fresh world."""
    st = init_state(cfg, key)
    horizon = jnp.asarray(n_steps, jnp.int32)
    _, metrics = rollout_chunk(
        st, SimMetrics.zeros(), sp, horizon, cfg, n_steps
    )
    return metrics
