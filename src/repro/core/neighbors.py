"""Single-pass neighborhood engine — the simulator's O(N²) hot spot, fused.

Every simulated vehicle-step needs "who is ahead of / behind me in lane q"
for several query lanes q: the own-lane IDM search, four searches inside the
two MOBIL candidate evaluations, the ramp-merge target search, the post-
lane-change recompute, and the collision/TTC check — historically ~8
independent O(N²) masked all-pairs scans per ``sim_step``.

This module answers all of them through one API with three interchangeable
implementations (``SimConfig.neighbor_impl``):

``reference``
    The original per-query masked all-pairs scan (``neighbor_info``), one
    O(N²) pass per lane table. Kept as the bit-for-bit parity oracle.
``dense``
    Fused dense path: materializes the pairwise ``dpos``/activity masks
    **once** per state snapshot and derives the per-lane lead/follower
    tables for all lanes in one batched ``[L, N, N]`` reduction.
``sort``
    O(L·N log N) path: one stable per-lane argsort of positions per
    snapshot (L = lane count, a small constant); every query is answered
    by ``searchsorted`` adjacency lookups in the sorted lane segments.
``pallas``
    TPU Pallas kernel (``repro.kernels.idm.neighbor_kernel``): a multi-query
    lead+follower search with VMEM-resident running minima, gridded over
    (query, ego-tile, other-tile). Interpret mode is auto-enabled off-TPU.

All implementations share one contract (the seed ``neighbor_info``
semantics, bit-for-bit):

- lead  = argmin over vehicles strictly ahead  (``pos_j > pos_i``) in q;
- foll  = argmin over vehicles strictly behind (``pos_j < pos_i``) in q;
- exact position ties (including self) are neither lead nor follower;
- index ties resolve to the lowest slot index (stable/first-minimum);
- absent neighbors report ``idx = 0``, ``gap = INF - veh_len``,
  ``has = False``; inactive queriers have no neighbors.

The engine exposes **per-lane tables**: for every lane ``l ∈ [0, L)`` and
every vehicle ``i``, the lead/follower of ``i`` *as if it were in lane l*.
Arbitrary per-vehicle query-lane vectors then become O(N) gathers, so one
table build serves every pre-move query of a step.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

INF = 1e9

IMPLS = ("reference", "dense", "sort", "pallas")


class Neighbors(NamedTuple):
    """Lead/follower answer for one query-lane vector. All fields [N]."""

    lead_idx: jax.Array   # i32, 0 when has_lead is False
    lead_gap: jax.Array   # f32 bumper-to-bumper, INF - veh_len when absent
    has_lead: jax.Array   # bool
    foll_idx: jax.Array   # i32
    foll_gap: jax.Array   # f32
    has_foll: jax.Array   # bool


class NeighborTables(NamedTuple):
    """Per-lane neighbor tables. All fields [L, N] (lane-major)."""

    lead_idx: jax.Array
    lead_gap: jax.Array
    has_lead: jax.Array
    foll_idx: jax.Array
    foll_gap: jax.Array
    has_foll: jax.Array

    def query(self, query_lane: jax.Array) -> Neighbors:
        """Answer a per-vehicle query-lane vector by gathering table rows."""
        cols = jnp.arange(query_lane.shape[0])
        return Neighbors(*(t[query_lane, cols] for t in self))


def neighbor_info(pos, lane, active, veh_len, query_lane):
    """Per-vehicle lead/follower in ``query_lane[i]`` (masked O(N²) search).

    The seed implementation and parity oracle. Returns (lead_idx, lead_gap,
    has_lead, foll_idx, foll_gap, has_foll); gaps are bumper-to-bumper.
    """
    dpos = pos[None, :] - pos[:, None]                      # [i,j] = pos_j - pos_i
    n = pos.shape[0]
    eye = jnp.eye(n, dtype=bool)
    pair_ok = (
        (lane[None, :] == query_lane[:, None])
        & active[None, :]
        & active[:, None]
        & ~eye
    )
    ahead = pair_ok & (dpos > 0.0)
    behind = pair_ok & (dpos <= 0.0) & ~(dpos == 0.0)       # strictly behind

    lead_d = jnp.where(ahead, dpos, INF)
    lead_idx = jnp.argmin(lead_d, axis=1)
    lead_gap = jnp.min(lead_d, axis=1) - veh_len
    has_lead = jnp.any(ahead, axis=1)

    foll_d = jnp.where(behind, -dpos, INF)
    foll_idx = jnp.argmin(foll_d, axis=1)
    foll_gap = jnp.min(foll_d, axis=1) - veh_len
    has_foll = jnp.any(behind, axis=1)
    return lead_idx, lead_gap, has_lead, foll_idx, foll_gap, has_foll


# --------------------------------------------------------------------------
# reference impl — per-lane wrapper over neighbor_info
# --------------------------------------------------------------------------

def _reference_tables(pos, lane, active, veh_len, n_lanes_total):
    def one(l):
        q = jnp.full_like(lane, l)
        return Neighbors(*neighbor_info(pos, lane, active, veh_len, q))

    return NeighborTables(*jax.vmap(one)(jnp.arange(n_lanes_total)))


# --------------------------------------------------------------------------
# fused dense impl — one [N,N] materialization, all lanes in one reduction
# --------------------------------------------------------------------------

def _dense_tables(pos, lane, active, veh_len, n_lanes_total):
    n = pos.shape[0]
    dpos = pos[None, :] - pos[:, None]
    eye = jnp.eye(n, dtype=bool)
    pair_act = active[None, :] & active[:, None] & ~eye
    ahead_act = pair_act & (dpos > 0.0)                     # [N,N], lane-free
    behind_act = pair_act & (dpos < 0.0)
    lanes = jnp.arange(n_lanes_total, dtype=lane.dtype)
    in_lane = lane[None, :] == lanes[:, None]               # [L,N] over j

    ahead = ahead_act[None] & in_lane[:, None, :]           # [L,N,N]
    behind = behind_act[None] & in_lane[:, None, :]

    lead_d = jnp.where(ahead, dpos[None], INF)
    lead_idx = jnp.argmin(lead_d, axis=2)
    lead_gap = jnp.min(lead_d, axis=2) - veh_len
    has_lead = jnp.any(ahead, axis=2)

    foll_d = jnp.where(behind, -dpos[None], INF)
    foll_idx = jnp.argmin(foll_d, axis=2)
    foll_gap = jnp.min(foll_d, axis=2) - veh_len
    has_foll = jnp.any(behind, axis=2)
    return NeighborTables(
        lead_idx, lead_gap, has_lead, foll_idx, foll_gap, has_foll
    )


# --------------------------------------------------------------------------
# sort impl — one stable argsort per lane, searchsorted adjacency queries
# --------------------------------------------------------------------------

def _sort_tables(pos, lane, active, veh_len, n_lanes_total):
    n = pos.shape[0]
    no_gap = jnp.asarray(INF, pos.dtype) - veh_len

    def one_lane(l):
        in_l = active & (lane == l)
        key = jnp.where(in_l, pos, INF)
        order = jnp.argsort(key, stable=True)   # in-lane ascending, rest last
        spos = key[order]

        # lead: first entry strictly greater than pos_i ('right' skips ties,
        # which also excludes self and exact-tie vehicles, matching the oracle)
        j = jnp.searchsorted(spos, pos, side="right")
        jc = jnp.minimum(j, n - 1)
        cand = spos[jc]
        has_lead = (j < n) & (cand < INF * 0.5) & active
        lead_idx = jnp.where(has_lead, order[jc], 0).astype(jnp.int32)
        lead_gap = jnp.where(has_lead, cand - pos - veh_len, no_gap)

        # follower: last entry strictly less than pos_i. Among equal
        # positions the oracle's argmin picks the lowest slot index, i.e.
        # the FIRST entry of the tied group in stable sort order — so hop
        # back to the start of the predecessor's tie group.
        j2 = jnp.searchsorted(spos, pos, side="left") - 1
        cand2 = spos[jnp.maximum(j2, 0)]
        jf = jnp.searchsorted(spos, cand2, side="left")
        has_foll = (j2 >= 0) & (cand2 < INF * 0.5) & active
        foll_idx = jnp.where(has_foll, order[jf], 0).astype(jnp.int32)
        foll_gap = jnp.where(has_foll, pos - cand2 - veh_len, no_gap)
        return Neighbors(
            lead_idx, lead_gap, has_lead, foll_idx, foll_gap, has_foll
        )

    return NeighborTables(*jax.vmap(one_lane)(jnp.arange(n_lanes_total)))


# --------------------------------------------------------------------------
# pallas impl — multi-query TPU kernel (interpret mode off-TPU)
# --------------------------------------------------------------------------

def _pallas_tables(pos, lane, active, veh_len, n_lanes_total, interpret):
    from repro.kernels import neighbor_kernel

    q = jnp.broadcast_to(
        jnp.arange(n_lanes_total, dtype=lane.dtype)[:, None],
        (n_lanes_total, pos.shape[0]),
    )
    return NeighborTables(
        *neighbor_kernel(
            pos, lane, active, q, veh_len=veh_len, interpret=interpret
        )
    )


# --------------------------------------------------------------------------
# engine entry points
# --------------------------------------------------------------------------

def _check_impl(impl: str) -> None:
    if impl not in IMPLS:
        raise ValueError(f"neighbor_impl must be one of {IMPLS}, got {impl!r}")


def build_tables(
    pos: jax.Array,
    lane: jax.Array,
    active: jax.Array,
    veh_len: float,
    n_lanes_total: int,
    impl: str = "dense",
    *,
    interpret: bool | None = None,
) -> NeighborTables:
    """Build per-lane lead/follower tables for one state snapshot.

    One call serves any number of per-vehicle query-lane vectors via
    ``tables.query(q)`` — this is the single fused pass that replaces the
    per-query O(N²) scans.
    """
    _check_impl(impl)
    if impl == "reference":
        return _reference_tables(pos, lane, active, veh_len, n_lanes_total)
    if impl == "dense":
        return _dense_tables(pos, lane, active, veh_len, n_lanes_total)
    if impl == "sort":
        return _sort_tables(pos, lane, active, veh_len, n_lanes_total)
    return _pallas_tables(pos, lane, active, veh_len, n_lanes_total, interpret)


def query_lanes(
    pos: jax.Array,
    lane: jax.Array,
    active: jax.Array,
    veh_len: float,
    query_lane: jax.Array,
    impl: str = "dense",
    *,
    n_lanes_total: int | None = None,
    interpret: bool | None = None,
) -> Neighbors:
    """Answer a single per-vehicle query-lane vector (one construction).

    Cheaper than ``build_tables`` when only one query is needed for a
    snapshot (the post-lane-change recompute).
    """
    _check_impl(impl)
    if impl in ("reference", "dense"):
        # a single query vector IS one masked all-pairs scan either way
        return Neighbors(*neighbor_info(pos, lane, active, veh_len, query_lane))
    if impl == "sort":
        # one table build is already O(N log N); gather the requested rows
        if n_lanes_total is None:
            raise ValueError(
                "query_lanes(impl='sort') needs n_lanes_total (the lane "
                "count is a static table dimension)"
            )
        tabs = _sort_tables(pos, lane, active, veh_len, n_lanes_total)
        return tabs.query(query_lane)
    from repro.kernels import neighbor_kernel

    res = neighbor_kernel(
        pos, lane, active, query_lane[None, :], veh_len=veh_len,
        interpret=interpret,
    )
    return Neighbors(*(t[0] for t in res))
