"""The paper's contribution: a parallel, fault-tolerant simulation sweep pipeline.

- :mod:`repro.core.scenario`  — randomized per-instance parameter sampling
  (the ``duarouter --randomize-flows --seed $RANDOM`` analogue).
- :mod:`repro.core.scenarios` — the Scenario API + registry: road geometry,
  parameter sampling and the three jit hook groups each workload plugs into
  the scenario-agnostic ``sim_step`` (highway_merge, lane_drop, stop_and_go,
  speed_limit_zone, ...).
- :mod:`repro.core.neighbors` — the single-pass neighborhood engine (fused
  dense / sort-based / Pallas lead+follower queries behind one API).
- :mod:`repro.core.simulator` — vectorized IDM+MOBIL merge simulator (the
  Webots+SUMO analogue), jit-compiled chunked rollouts.
- :mod:`repro.core.sweep`     — the PBS-job-array analogue: instance sharding
  over the device mesh, walltime-slice chunking.
- :mod:`repro.core.fault`     — completion bitmap, checkpoint/restart,
  failure injection (the full crash/hang/straggler/corruption taxonomy of
  ``FaultModel``), straggler mitigation, elastic re-meshing.
- :mod:`repro.core.fleet`     — unattended-run supervision: retry budgets
  with exponential backoff, quarantine for poison instances, the
  crash-safe run journal and the §5.2 completion report.
- :mod:`repro.core.aggregate` — big-data output aggregation (paper §2.10).
- :mod:`repro.core.tokens`    — trajectory → token streams (Phase III bridge).
- :mod:`repro.core.metrics`   — throughput/distribution accounting (paper §5).
"""

from repro.core.scenario import SimConfig, ScenarioParams, sample_scenario_params
from repro.core.scenarios import (
    RoadGeometry,
    Scenario,
    get_scenario,
    list_scenarios,
    register_scenario,
    scenario_index,
)
from repro.core.neighbors import (
    Neighbors,
    NeighborTables,
    build_tables,
    neighbor_info,
    query_lanes,
)
from repro.core.simulator import (
    SimState,
    SimMetrics,
    init_state,
    sim_step,
    rollout_chunk,
    rollout_chunk_rec,
    rollout,
)
from repro.core.record import RecordConfig, TraceBuffer

__all__ = [
    "SimConfig",
    "ScenarioParams",
    "sample_scenario_params",
    "RoadGeometry",
    "Scenario",
    "get_scenario",
    "list_scenarios",
    "register_scenario",
    "scenario_index",
    "Neighbors",
    "NeighborTables",
    "build_tables",
    "neighbor_info",
    "query_lanes",
    "SimState",
    "SimMetrics",
    "init_state",
    "sim_step",
    "rollout_chunk",
    "rollout_chunk_rec",
    "rollout",
    "RecordConfig",
    "TraceBuffer",
]
