"""Trajectory → token streams: the Phase-III bridge.

The paper's end goal is ML on the aggregated simulation dataset. The LM
training stack in this framework consumes *token* streams, so simulation
trajectories are serialized into a compact discrete vocabulary:

    [BOS] (step frame) [SEP] (step frame) ... [EOS] [PAD]*

where a step frame emits, for each tracked vehicle slot, one token encoding
(lane, speed bucket): ``token = 4 + lane * n_buckets + bucket``. The
vocabulary is ``4 + (n_lanes+2) * n_buckets`` (slot-inactive gets its own
lane code). Any LM architecture in the zoo can train on these streams
(`examples/train_lm.py` does).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scenario import SimConfig, ScenarioParams
from repro.core.simulator import SimState, SimMetrics, sim_step, init_state, _acc

PAD, BOS, EOS, SEP = 0, 1, 2, 3
SPECIAL = 4


class Trajectory(NamedTuple):
    lane: jax.Array   # [T, K] i32, n_lanes+1 == inactive code
    speed: jax.Array  # [T, K] f32
    active: jax.Array # [T, K] bool


def vocab_size(cfg: SimConfig, n_buckets: int = 16) -> int:
    # lanes 0..n_lanes (ramp) plus one inactive code
    return SPECIAL + (cfg.n_lanes + 2) * n_buckets


@functools.partial(
    jax.jit, static_argnames=("cfg", "n_steps", "record_every", "k_slots")
)
def record_rollout(
    key: jax.Array,
    sp: ScenarioParams,
    cfg: SimConfig,
    n_steps: int,
    record_every: int = 10,
    k_slots: int = 16,
) -> tuple[SimMetrics, Trajectory]:
    """Roll an episode, recording the first ``k_slots`` vehicle slots every
    ``record_every`` steps."""
    st = init_state(cfg, key)

    def body(carry, _):
        st, m = carry
        st, d = sim_step(st, cfg, sp)
        m = _acc(m, d)
        snap = (st.lane[:k_slots], st.vel[:k_slots], st.active[:k_slots])
        return (st, m), snap

    (_, metrics), (lanes, vels, actives) = jax.lax.scan(
        body, (st, SimMetrics.zeros()), None, length=n_steps
    )
    sl = slice(record_every - 1, None, record_every)
    return metrics, Trajectory(lanes[sl], vels[sl], actives[sl])


def _frame_tokens(lane, speed, active, cfg: SimConfig, n_buckets: int,
                  v_max: float) -> jax.Array:
    """Per-vehicle token code for (lane, speed, active) channels — the ONE
    definition of the frame encoding, shared by the single-trajectory and
    batched-trace serializers so they can never drift apart."""
    bucket = jnp.clip(
        (speed / v_max * n_buckets).astype(jnp.int32), 0, n_buckets - 1
    )
    lane_code = jnp.where(active, lane, cfg.n_lanes + 1)
    return SPECIAL + lane_code * n_buckets + bucket


def trajectory_to_tokens(
    traj: Trajectory, cfg: SimConfig, n_buckets: int = 16,
    v_max: float = 40.0,
) -> jax.Array:
    """Serialize one trajectory into a 1-D token stream (see module doc)."""
    t, k = traj.lane.shape
    tok = _frame_tokens(traj.lane, traj.speed, traj.active, cfg,
                        n_buckets, v_max)                    # [T, K]
    frames = jnp.concatenate(
        [tok, jnp.full((t, 1), SEP, tok.dtype)], axis=1
    ).reshape(-1)
    return jnp.concatenate(
        [jnp.array([BOS], tok.dtype), frames, jnp.array([EOS], tok.dtype)]
    )


def trace_token_streams(
    lane,
    speed,
    active,
    valid_rows,
    cfg: SimConfig,
    n_buckets: int = 16,
    v_max: float = 40.0,
) -> tuple[np.ndarray, np.ndarray]:
    """Batched sweep-trace channels → padded token streams.

    The sweep recorder (:mod:`repro.core.record`) produces ``[N, R, K]``
    (lane, speed, active) slabs whose per-instance filled-row count
    ``valid_rows[i] = horizon[i] // record_every`` varies (straggler
    populations). This serializes each instance with the same frame code as
    :func:`trajectory_to_tokens` — ``[BOS] frames [EOS]`` — fixed-shape to
    ``L = R*(K+1) + 2`` with trailing ``PAD``. Returns ``([N, L] i32
    streams, [N] stream lengths incl. BOS/EOS)``. Host-side numpy: this is
    dataset prep at chunk boundaries, not jit territory.
    """
    lane = np.asarray(lane)
    speed = np.asarray(speed, np.float32)
    active = np.asarray(active)
    valid = np.asarray(valid_rows).astype(np.int64)
    n, r, k = lane.shape
    tok = np.asarray(
        _frame_tokens(lane, speed, active, cfg, n_buckets, v_max)
    ).astype(np.int32)
    fw = k + 1  # frame width: k vehicle tokens + SEP
    frames = np.concatenate(
        [tok, np.full((n, r, 1), SEP, np.int32)], axis=2
    ).reshape(n, r * fw)
    mask = np.arange(r * fw)[None, :] < (valid * fw)[:, None]
    out = np.full((n, r * fw + 2), PAD, np.int32)
    out[:, 0] = BOS
    out[:, 1 : 1 + r * fw] = np.where(mask, frames, PAD)
    out[np.arange(n), 1 + valid * fw] = EOS
    return out, 2 + valid * fw


def sweep_token_dataset(
    keys: jax.Array,
    params: ScenarioParams,
    cfg: SimConfig,
    n_steps: int = 600,
    record_every: int = 10,
    k_slots: int = 16,
    n_buckets: int = 16,
) -> jax.Array:
    """Batched: [n_instances] keys + stacked params → [n, stream_len] tokens."""

    def one(key, sp):
        _, traj = record_rollout(
            key, sp, cfg, n_steps, record_every, k_slots
        )
        return trajectory_to_tokens(traj, cfg, n_buckets)

    return jax.vmap(one)(keys, params)
