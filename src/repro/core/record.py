"""Streaming trajectory recording — the Phase-III dataset subsystem.

The paper's pipeline exists so users can "generate massive datasets from
their simulations" (§2.10, Phase III). Terminal :class:`SimMetrics` scalars
are a digest, not a dataset: ML wants *time series*. This module adds a
recording channel to the sweep engine:

- :class:`RecordConfig` — a static (hashable, jit-compile-time) description
  of what to record: named scalar channels (speeds, flows, lane-change and
  safety counters — see :data:`FIELD_CHANNELS`), a ``record_every`` step
  stride, and the first ``k_slots`` vehicle slots' (lane, speed, active)
  trajectory used by the token serializer (:mod:`repro.core.tokens`).
- :class:`TraceBuffer` — a fixed-shape per-instance row buffer the rollout
  fills on-device. Rows are indexed by **absolute step count**
  (row ``r`` holds the snapshot after step ``(r+1)·record_every``), so a
  write is a pure function of the instance's simulation state:

  * chunk boundaries don't matter (chunk-size invariance holds bitwise),
  * a re-executed chunk (fault revert, checkpoint resume) rewrites the
    same rows with identical values — recording never drops or duplicates
    a row, by construction,
  * the buffer rides :class:`~repro.core.sweep.SweepState` in LOGICAL
    instance order through the chunk planner's gather/scatter, so it is
    dispatch-agnostic across ``switch``/``grouped``/compaction — and
    sharding-agnostic across device counts (the N-device executor's LPT
    block packing is just another physical-row permutation; rows come
    back to logical slots before anything reads them) — for free.

The sweep loop drains completed instances' rows to host at chunk
boundaries (:class:`repro.data.shards.DatasetWriter`), turning every sweep
into a sharded, resumable dataset.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

# Scalar channels recordable per sampled step. Each extractor maps the
# *post-step* (state, accumulated-metrics) pair to one f32 scalar. Counter
# channels record the CUMULATIVE value at the sampled step — windowed rates
# (flow, lane-change rate, crash rate) are recovered by differencing rows,
# and cumulative values make re-executed chunks trivially idempotent.
FIELD_CHANNELS = {
    "mean_speed": lambda st, m: (
        jnp.sum(jnp.where(st.active, st.vel, 0.0))
        / jnp.maximum(jnp.sum(st.active.astype(jnp.float32)), 1.0)
    ),
    "active_count": lambda st, m: jnp.sum(st.active.astype(jnp.float32)),
    "throughput": lambda st, m: m.throughput.astype(jnp.float32),
    "spawned": lambda st, m: m.spawned.astype(jnp.float32),
    "lane_changes": lambda st, m: m.lane_changes.astype(jnp.float32),
    "merges_ok": lambda st, m: m.merges_ok.astype(jnp.float32),
    "collisions": lambda st, m: m.collisions.astype(jnp.float32),
    "ramp_blocked_steps": lambda st, m: (
        m.ramp_blocked_steps.astype(jnp.float32)
    ),
    "min_ttc": lambda st, m: m.min_ttc,
}

DEFAULT_FIELDS = (
    "mean_speed",
    "active_count",
    "throughput",
    "lane_changes",
    "collisions",
    "min_ttc",
)


@dataclass(frozen=True)
class RecordConfig:
    """Static recording description (a jit compile-time constant).

    ``fields`` name scalar channels from :data:`FIELD_CHANNELS`; ``k_slots``
    vehicle slots additionally record (lane, speed, active) per sampled step
    — the token-stream channels. ``record_every`` is the sampling stride in
    physics steps: row ``r`` is the snapshot after step
    ``(r+1)*record_every``.
    """

    record_every: int = 10
    fields: tuple[str, ...] = DEFAULT_FIELDS
    k_slots: int = 0

    def __post_init__(self) -> None:
        if self.record_every < 1:
            raise ValueError(f"record_every must be >= 1, got {self.record_every}")
        if self.k_slots < 0:
            raise ValueError(f"k_slots must be >= 0, got {self.k_slots}")
        unknown = [f for f in self.fields if f not in FIELD_CHANNELS]
        if unknown:
            raise ValueError(
                f"unknown record fields {unknown}; known: "
                f"{sorted(FIELD_CHANNELS)}"
            )
        if not self.fields and not self.k_slots:
            raise ValueError("RecordConfig records nothing: empty fields "
                             "and k_slots=0")

    def n_rows(self, steps: int) -> int:
        """Rows a horizon of ``steps`` fills (only complete strides)."""
        return steps // self.record_every


class TraceBuffer(NamedTuple):
    """Per-instance recorded time series (vmapped to a leading [N] axis).

    ``series[r, f]`` is channel ``fields[f]`` after step
    ``(r+1)*record_every``; ``lane/speed/active[r, k]`` are the first
    ``k_slots`` vehicle slots at the same instant. Rows beyond the
    instance's ``horizon // record_every`` stay at their zero fill — the
    valid-row count is derived from the horizon, never stored.
    """

    series: jax.Array  # [R, F] f32
    lane: jax.Array    # [R, K] i32
    speed: jax.Array   # [R, K] f32
    active: jax.Array  # [R, K] bool

    @staticmethod
    def zeros(rec: RecordConfig, steps: int) -> "TraceBuffer":
        r = rec.n_rows(steps)
        k = rec.k_slots
        return TraceBuffer(
            series=jnp.zeros((r, len(rec.fields)), jnp.float32),
            lane=jnp.zeros((r, k), jnp.int32),
            speed=jnp.zeros((r, k), jnp.float32),
            active=jnp.zeros((r, k), bool),
        )


def batch_zeros(rec: RecordConfig, steps: int, n_instances: int) -> TraceBuffer:
    """[N]-stacked empty buffers (the sweep's initial ``SweepState.trace``)."""
    proto = TraceBuffer.zeros(rec, steps)
    return jax.tree.map(
        lambda x: jnp.zeros((n_instances,) + x.shape, x.dtype), proto
    )


def record_step(
    tr: TraceBuffer, st, m, rec: RecordConfig, emit: jax.Array
) -> TraceBuffer:
    """Write one row if ``emit`` and the state sits on the stride.

    ``st``/``m`` are the *post-step* state and accumulated metrics
    (``st.t`` already incremented). ``emit`` must be False for stale
    states (an instance past its horizon). Off-stride or non-emitting
    writes target an out-of-range row that ``mode="drop"`` discards, so
    the emitted program is branch-free (vmap/scan friendly).

    Called once per physics step on the fallback path, or once per
    stride *window* on the fast path (see
    :func:`repro.core.simulator.rollout_chunk_rec`) — either way the row
    is a pure function of the instance's simulation state, which is what
    every parity property rests on.
    """
    n_rows = tr.series.shape[0]
    t1 = st.t
    emit = emit & (jnp.mod(t1, rec.record_every) == 0)
    idx = jnp.where(emit, t1 // rec.record_every - 1, n_rows)
    vals = (
        jnp.stack([FIELD_CHANNELS[f](st, m) for f in rec.fields])
        if rec.fields
        else jnp.zeros((0,), jnp.float32)
    )
    tr = tr._replace(series=tr.series.at[idx].set(vals, mode="drop"))
    if rec.k_slots:
        k = rec.k_slots
        tr = tr._replace(
            lane=tr.lane.at[idx].set(st.lane[:k], mode="drop"),
            speed=tr.speed.at[idx].set(st.vel[:k], mode="drop"),
            active=tr.active.at[idx].set(st.active[:k], mode="drop"),
        )
    return tr


def valid_rows(horizon, record_every: int):
    """Per-instance count of filled rows (works on numpy or jnp arrays)."""
    return horizon // record_every
