"""Throughput & distribution accounting — the paper's §5 evaluation harness.

Reproduces the bookkeeping behind:
- Table 5.1 / Fig 5.1: completed runs over time, cluster vs personal computer
  (48·t per 15-minute slice; 2,304 vs 74 after 12 h → ~31×).
- §5.2: distribution evenness (exactly ``per_node`` instances per node per
  slice, 100 % of the time).
- Tables 5.2/5.3 / Fig 5.2: parallel (6×8) vs serial (6×1) configurations.

Plus the scheduling pieces the paper delegates to PBS: block assignment of
array elements to nodes, and an LPT (longest-processing-time) balancer used
when instance costs vary (straggler-aware assignment, DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """The paper's experimental setup: 6 DICE-lab nodes × 8 instances."""

    n_nodes: int = 6
    instances_per_node: int = 8
    walltime_min: float = 15.0  # per job slice

    @property
    def batch_per_slice(self) -> int:
        return self.n_nodes * self.instances_per_node


def cluster_timeline(
    spec: ClusterSpec, timestamps_min: list[float]
) -> list[int]:
    """Completed runs at each timestamp — paper Table 5.1 cluster column."""
    return [
        int(t // spec.walltime_min) * spec.batch_per_slice
        for t in timestamps_min
    ]


def personal_timeline(
    run_minutes: float, timestamps_min: list[float]
) -> list[int]:
    """Completed runs on a single sequential machine (paper PC column).

    The paper's PC completes 74 runs in 720 min → ~9.73 min/run.
    (1e-9 guard: t an exact multiple of run_minutes counts the finished run.)
    """
    return [int(t / run_minutes + 1e-9) for t in timestamps_min]


PAPER_TIMESTAMPS = [30, 60, 90, 120, 240, 360, 720]
PAPER_PC = [4, 7, 11, 15, 26, 40, 74]
PAPER_CLUSTER = [96, 192, 288, 384, 768, 1152, 2304]


def block_assignment(n_instances: int, n_workers: int) -> np.ndarray:
    """PBS-style contiguous block assignment: instance → worker id."""
    per = -(-n_instances // n_workers)
    return np.minimum(np.arange(n_instances) // per, n_workers - 1)


def lpt_assignment(costs: np.ndarray, n_workers: int) -> np.ndarray:
    """Longest-processing-time greedy: balances variable-cost instances."""
    order = np.argsort(-np.asarray(costs))
    loads = np.zeros(n_workers)
    assign = np.zeros(len(costs), dtype=np.int64)
    for i in order:
        w = int(np.argmin(loads))
        assign[i] = w
        loads[w] += costs[i]
    return assign


def makespan(costs: np.ndarray, assign: np.ndarray, n_workers: int) -> float:
    loads = np.zeros(n_workers)
    np.add.at(loads, assign, costs)
    return float(loads.max())


def distribution_evenness(assign: np.ndarray, n_workers: int) -> dict:
    """§5.2 metric: how evenly instances land on workers."""
    counts = np.bincount(assign, minlength=n_workers)
    return {
        "min": int(counts.min()),
        "max": int(counts.max()),
        "perfectly_even": bool(counts.max() - counts.min() <= 1),
        "counts": counts.tolist(),
    }


def speedup_at(
    spec: ClusterSpec, pc_run_minutes: float, at_min: float
) -> float:
    """Cluster-vs-PC completed-run ratio at time ``at_min`` (paper: ~31×)."""
    cluster = cluster_timeline(spec, [at_min])[0]
    pc = personal_timeline(pc_run_minutes, [at_min])[0]
    return cluster / max(pc, 1)
