"""Neighborhood search + IDM acceleration (TPU Pallas) — the simulator's hot spot.

The paper's simulation engine (Webots physics + SUMO car following) reduces,
per step, to: for every vehicle find the nearest same-lane leader, then apply
IDM. That is an O(N²) masked min-reduction — on TPU, a tiled VPU problem.

``idm_accel_kernel`` (the original, lead-only form):
grid ``(nI, nJ)`` over (ego-tile, other-tile); the running minimum gap and
the lead's velocity live in VMEM scratch across J tiles (minor grid dim);
the final J step computes the IDM formula and writes accelerations.

``neighbor_kernel`` (the neighborhood engine's generalized form):
grid ``(Q, nI, nJ)`` over (query-lane-vector, ego-tile, other-tile). For each
of Q per-vehicle query-lane vectors it returns lead **and** follower
(idx, gap, has) in one launch — the ~8 per-step O(N²) searches of
``sim_step`` collapse into one kernel invocation per state snapshot. Running
(gap, idx) minima for both directions live in VMEM scratch; ties resolve to
the lowest slot index (strict-< running update + first-argmin within a
tile), matching the jnp oracle bit-for-bit.
Lead velocity is recovered with the classic two-pass-free trick: minimize a
packed key ``gap·SCALE + rank(vel)`` — but here we simply carry both the min
gap and an argmin-selected velocity via ``where`` updates, which the VPU
handles natively. Vehicle count is padded to the 128-lane boundary; inactive
slots sit at pos = −INF and never win a minimum.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INF = 1e9


def _idm_kernel(
    pos_ref, vel_ref, lane_ref, act_ref,                 # ego tile [1, BI]
    pos_j_ref, vel_j_ref, lane_j_ref, act_j_ref,         # other tile [1, BJ]
    v0_ref, T_ref, amax_ref, bcomf_ref, s0_ref,          # ego params [1, BI]
    acc_ref,                                             # out [1, BI]
    gap_ref, vlead_ref,                                  # scratch [1, BI] f32
    *,
    veh_len: float,
):
    ij = pl.program_id(1)

    @pl.when(ij == 0)
    def _init():
        gap_ref[...] = jnp.full_like(gap_ref, INF)
        vlead_ref[...] = jnp.zeros_like(vlead_ref)

    pos_i = pos_ref[0]                                   # [BI]
    pos_j = pos_j_ref[0]                                 # [BJ]
    dpos = pos_j[None, :] - pos_i[:, None]               # [BI, BJ]
    ok = (
        (lane_j_ref[0][None, :] == lane_ref[0][:, None])
        & act_j_ref[0][None, :]
        & act_ref[0][:, None]
        & (dpos > 0.0)
    )
    d = jnp.where(ok, dpos, INF)
    tile_min = d.min(axis=1)                             # [BI]
    idx = d.argmin(axis=1)                               # [BI]
    tile_vlead = jnp.take(vel_j_ref[0], idx)

    better = tile_min < gap_ref[0]
    gap_ref[0] = jnp.where(better, tile_min, gap_ref[0])
    vlead_ref[0] = jnp.where(better, tile_vlead, vlead_ref[0])

    @pl.when(ij == pl.num_programs(1) - 1)
    def _finish():
        vel = vel_ref[0]
        has_lead = gap_ref[0] < INF * 0.5
        gap = jnp.maximum(
            jnp.where(has_lead, gap_ref[0] - veh_len, INF), 0.1
        )
        dv = jnp.where(has_lead, vel - vlead_ref[0], 0.0)
        a_max = amax_ref[0]
        s_star = s0_ref[0] + jnp.maximum(
            0.0,
            vel * T_ref[0]
            + vel * dv / (2.0 * jnp.sqrt(a_max * bcomf_ref[0])),
        )
        acc = a_max * (
            1.0
            - (vel / jnp.maximum(v0_ref[0], 0.1)) ** 4
            - (s_star / gap) ** 2
        )
        acc_ref[0] = acc.astype(acc_ref.dtype)


@functools.partial(jax.jit, static_argnames=("veh_len", "block", "interpret"))
def idm_accel_kernel(
    pos: jax.Array, vel: jax.Array, lane: jax.Array, active: jax.Array,
    v0: jax.Array, T: jax.Array, a_max: jax.Array, b_comf: jax.Array,
    s0: jax.Array,
    *,
    veh_len: float = 4.5,
    block: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """[N] arrays → [N] accelerations. N is padded to the lane boundary."""
    n = pos.shape[0]
    bi = bj = min(block, max(n, 8))
    pad = (-n) % bi
    if pad:
        def padf(x, fill):
            return jnp.pad(x, (0, pad), constant_values=fill)

        pos = padf(pos, -INF)
        vel = padf(vel, 0.0)
        lane = padf(lane, -1)
        active = padf(active, False)
        v0 = padf(v0, 1.0)
        T = padf(T, 1.0)
        a_max = padf(a_max, 1.0)
        b_comf = padf(b_comf, 1.0)
        s0 = padf(s0, 1.0)
    npad = pos.shape[0]

    def r1(x):
        return x.reshape(1, npad)

    ego_spec = pl.BlockSpec((1, bi), lambda i, j: (0, i))
    oth_spec = pl.BlockSpec((1, bj), lambda i, j: (0, j))
    kernel = functools.partial(_idm_kernel, veh_len=veh_len)
    acc = pl.pallas_call(
        kernel,
        grid=(npad // bi, npad // bj),
        in_specs=[ego_spec, ego_spec, ego_spec, ego_spec,
                  oth_spec, oth_spec, oth_spec, oth_spec,
                  ego_spec, ego_spec, ego_spec, ego_spec, ego_spec],
        out_specs=pl.BlockSpec((1, bi), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, npad), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, bi), jnp.float32),
            pltpu.VMEM((1, bi), jnp.float32),
        ],
        interpret=interpret,
    )(
        r1(pos), r1(vel), r1(lane), r1(active),
        r1(pos), r1(vel), r1(lane), r1(active),
        r1(v0), r1(T), r1(a_max), r1(b_comf), r1(s0),
    )
    return acc[0, :n]


# --------------------------------------------------------------------------
# generalized multi-query lead+follower kernel (the neighborhood engine)
# --------------------------------------------------------------------------

def _neighbor_mq_kernel(
    pos_ref, act_ref, qlane_ref,                          # ego tile [1, BI]
    pos_j_ref, lane_j_ref, act_j_ref,                     # other tile [1, BJ]
    li_ref, lg_ref, lh_ref, fi_ref, fg_ref, fh_ref,       # out [1, BI]
    lgap_s, lidx_s, fgap_s, fidx_s,                       # scratch [1, BI]
    *,
    veh_len: float,
    bj: int,
):
    ij = pl.program_id(2)

    @pl.when(ij == 0)
    def _init():
        lgap_s[...] = jnp.full_like(lgap_s, INF)
        lidx_s[...] = jnp.zeros_like(lidx_s)
        fgap_s[...] = jnp.full_like(fgap_s, INF)
        fidx_s[...] = jnp.zeros_like(fidx_s)

    pos_i = pos_ref[0]                                    # [BI]
    pos_j = pos_j_ref[0]                                  # [BJ]
    dpos = pos_j[None, :] - pos_i[:, None]                # [BI, BJ]
    ok = (
        (lane_j_ref[0][None, :] == qlane_ref[0][:, None])
        & act_j_ref[0][None, :]
        & act_ref[0][:, None]
    )
    base = (ij * bj).astype(jnp.int32)

    def fold(d, gap_s, idx_s):
        tile_min = d.min(axis=1)                          # [BI]
        tile_idx = base + d.argmin(axis=1).astype(jnp.int32)
        better = tile_min < gap_s[0]                      # ties keep lower j
        gap_s[0] = jnp.where(better, tile_min, gap_s[0])
        idx_s[0] = jnp.where(better, tile_idx, idx_s[0])

    fold(jnp.where(ok & (dpos > 0.0), dpos, INF), lgap_s, lidx_s)
    fold(jnp.where(ok & (dpos < 0.0), -dpos, INF), fgap_s, fidx_s)

    @pl.when(ij == pl.num_programs(2) - 1)
    def _finish():
        has_l = lgap_s[0] < INF * 0.5
        has_f = fgap_s[0] < INF * 0.5
        lg_ref[0] = lgap_s[0] - veh_len
        li_ref[0] = jnp.where(has_l, lidx_s[0], 0)
        lh_ref[0] = has_l.astype(jnp.int32)
        fg_ref[0] = fgap_s[0] - veh_len
        fi_ref[0] = jnp.where(has_f, fidx_s[0], 0)
        fh_ref[0] = has_f.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("veh_len", "block", "interpret"))
def neighbor_kernel(
    pos: jax.Array, lane: jax.Array, active: jax.Array,
    query_lanes: jax.Array,
    *,
    veh_len: float = 4.5,
    block: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, ...]:
    """Multi-query lead+follower search.

    ``query_lanes`` is ``[Q, N]`` (Q per-vehicle query-lane vectors).
    Returns ``(lead_idx, lead_gap, has_lead, foll_idx, foll_gap, has_foll)``,
    each ``[Q, N]``; semantics match ``repro.core.neighbors.neighbor_info``
    bit-for-bit (absent: idx 0, gap INF − veh_len, has False).
    """
    n = pos.shape[0]
    nq = query_lanes.shape[0]
    bi = bj = min(block, max(n, 8))
    pad = (-n) % bi
    if pad:
        pos = jnp.pad(pos, (0, pad), constant_values=-INF)
        lane = jnp.pad(lane, (0, pad), constant_values=-1)
        active = jnp.pad(active, (0, pad), constant_values=False)
        query_lanes = jnp.pad(query_lanes, ((0, 0), (0, pad)),
                              constant_values=0)
    npad = pos.shape[0]

    def r1(x):
        return x.reshape(1, npad)

    ego_spec = pl.BlockSpec((1, bi), lambda q, i, j: (0, i))
    qln_spec = pl.BlockSpec((1, bi), lambda q, i, j: (q, i))
    oth_spec = pl.BlockSpec((1, bj), lambda q, i, j: (0, j))
    out_spec = pl.BlockSpec((1, bi), lambda q, i, j: (q, i))
    kernel = functools.partial(_neighbor_mq_kernel, veh_len=veh_len, bj=bj)
    shp = jax.ShapeDtypeStruct
    li, lg, lh, fi, fg, fh = pl.pallas_call(
        kernel,
        grid=(nq, npad // bi, npad // bj),
        in_specs=[ego_spec, ego_spec, qln_spec,
                  oth_spec, oth_spec, oth_spec],
        out_specs=[out_spec] * 6,
        out_shape=[
            shp((nq, npad), jnp.int32), shp((nq, npad), jnp.float32),
            shp((nq, npad), jnp.int32), shp((nq, npad), jnp.int32),
            shp((nq, npad), jnp.float32), shp((nq, npad), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, bi), jnp.float32),
            pltpu.VMEM((1, bi), jnp.int32),
            pltpu.VMEM((1, bi), jnp.float32),
            pltpu.VMEM((1, bi), jnp.int32),
        ],
        interpret=interpret,
    )(
        r1(pos), r1(active), query_lanes,
        r1(pos), r1(lane), r1(active),
    )
    return (
        li[:, :n], lg[:, :n], lh[:, :n].astype(bool),
        fi[:, :n], fg[:, :n], fh[:, :n].astype(bool),
    )
