"""IDM lead-search + acceleration (TPU Pallas) — the simulator's hot spot.

The paper's simulation engine (Webots physics + SUMO car following) reduces,
per step, to: for every vehicle find the nearest same-lane leader, then apply
IDM. That is an O(N²) masked min-reduction — on TPU, a tiled VPU problem.

Grid: ``(nI, nJ)`` over (ego-tile, other-tile); the running minimum gap and
the lead's velocity live in VMEM scratch across J tiles (minor grid dim);
the final J step computes the IDM formula and writes accelerations.
Lead velocity is recovered with the classic two-pass-free trick: minimize a
packed key ``gap·SCALE + rank(vel)`` — but here we simply carry both the min
gap and an argmin-selected velocity via ``where`` updates, which the VPU
handles natively. Vehicle count is padded to the 128-lane boundary; inactive
slots sit at pos = −INF and never win a minimum.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INF = 1e9


def _idm_kernel(
    pos_ref, vel_ref, lane_ref, act_ref,                 # ego tile [1, BI]
    pos_j_ref, vel_j_ref, lane_j_ref, act_j_ref,         # other tile [1, BJ]
    v0_ref, T_ref, amax_ref, bcomf_ref, s0_ref,          # ego params [1, BI]
    acc_ref,                                             # out [1, BI]
    gap_ref, vlead_ref,                                  # scratch [1, BI] f32
    *,
    veh_len: float,
):
    ij = pl.program_id(1)

    @pl.when(ij == 0)
    def _init():
        gap_ref[...] = jnp.full_like(gap_ref, INF)
        vlead_ref[...] = jnp.zeros_like(vlead_ref)

    pos_i = pos_ref[0]                                   # [BI]
    pos_j = pos_j_ref[0]                                 # [BJ]
    dpos = pos_j[None, :] - pos_i[:, None]               # [BI, BJ]
    ok = (
        (lane_j_ref[0][None, :] == lane_ref[0][:, None])
        & act_j_ref[0][None, :]
        & act_ref[0][:, None]
        & (dpos > 0.0)
    )
    d = jnp.where(ok, dpos, INF)
    tile_min = d.min(axis=1)                             # [BI]
    idx = d.argmin(axis=1)                               # [BI]
    tile_vlead = jnp.take(vel_j_ref[0], idx)

    better = tile_min < gap_ref[0]
    gap_ref[0] = jnp.where(better, tile_min, gap_ref[0])
    vlead_ref[0] = jnp.where(better, tile_vlead, vlead_ref[0])

    @pl.when(ij == pl.num_programs(1) - 1)
    def _finish():
        vel = vel_ref[0]
        has_lead = gap_ref[0] < INF * 0.5
        gap = jnp.maximum(
            jnp.where(has_lead, gap_ref[0] - veh_len, INF), 0.1
        )
        dv = jnp.where(has_lead, vel - vlead_ref[0], 0.0)
        a_max = amax_ref[0]
        s_star = s0_ref[0] + jnp.maximum(
            0.0,
            vel * T_ref[0]
            + vel * dv / (2.0 * jnp.sqrt(a_max * bcomf_ref[0])),
        )
        acc = a_max * (
            1.0
            - (vel / jnp.maximum(v0_ref[0], 0.1)) ** 4
            - (s_star / gap) ** 2
        )
        acc_ref[0] = acc.astype(acc_ref.dtype)


@functools.partial(jax.jit, static_argnames=("veh_len", "block", "interpret"))
def idm_accel_kernel(
    pos: jax.Array, vel: jax.Array, lane: jax.Array, active: jax.Array,
    v0: jax.Array, T: jax.Array, a_max: jax.Array, b_comf: jax.Array,
    s0: jax.Array,
    *,
    veh_len: float = 4.5,
    block: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """[N] arrays → [N] accelerations. N is padded to the lane boundary."""
    n = pos.shape[0]
    bi = bj = min(block, max(n, 8))
    pad = (-n) % bi
    if pad:
        def padf(x, fill):
            return jnp.pad(x, (0, pad), constant_values=fill)

        pos = padf(pos, -INF)
        vel = padf(vel, 0.0)
        lane = padf(lane, -1)
        active = padf(active, False)
        v0 = padf(v0, 1.0)
        T = padf(T, 1.0)
        a_max = padf(a_max, 1.0)
        b_comf = padf(b_comf, 1.0)
        s0 = padf(s0, 1.0)
    npad = pos.shape[0]

    def r1(x):
        return x.reshape(1, npad)

    ego_spec = pl.BlockSpec((1, bi), lambda i, j: (0, i))
    oth_spec = pl.BlockSpec((1, bj), lambda i, j: (0, j))
    kernel = functools.partial(_idm_kernel, veh_len=veh_len)
    acc = pl.pallas_call(
        kernel,
        grid=(npad // bi, npad // bj),
        in_specs=[ego_spec, ego_spec, ego_spec, ego_spec,
                  oth_spec, oth_spec, oth_spec, oth_spec,
                  ego_spec, ego_spec, ego_spec, ego_spec, ego_spec],
        out_specs=pl.BlockSpec((1, bi), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, npad), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, bi), jnp.float32),
            pltpu.VMEM((1, bi), jnp.float32),
        ],
        interpret=interpret,
    )(
        r1(pos), r1(vel), r1(lane), r1(active),
        r1(pos), r1(vel), r1(lane), r1(active),
        r1(v0), r1(T), r1(a_max), r1(b_comf), r1(s0),
    )
    return acc[0, :n]
