"""Flash attention (TPU Pallas): online-softmax tiling in VMEM.

Supports causal masking, sliding windows (gemma2 local layers), GQA head
grouping (q-head → kv-head = h // group) and Gemma-2 attention logit softcap.

Grid: ``(B, H, nQ, nK)`` — the KV axis is the minor (sequential) grid dim, so
running max/sum/accumulator live in VMEM scratch across KV tiles (the
canonical TPU flash schedule; no HBM round-trips for the softmax state).
Block shapes are MXU-aligned: q tile ``[BQ, D]``, kv tile ``[BK, D]`` with
BQ = BK = 128 by default and D ∈ {64, 128, 256}.

VMEM working set per step ≈ BQ·D (q) + 2·BK·D (k,v) + BQ·BK (scores f32)
+ BQ·D (acc f32) ≈ 0.5 MB at defaults — comfortably inside the ~16 MB/core
budget, leaving room for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30


def _flash_kernel(
    q_ref, k_ref, v_ref,            # [1, BQ, 1, D], [1, BK, 1, D]
    o_ref,                          # [1, BQ, 1, D]
    m_ref, l_ref, acc_ref,          # scratch: [BQ,1], [BQ,1], [BQ,D]
    *,
    scale: float,
    causal: bool,
    window: int,
    softcap: float,
    bq: int,
    bk: int,
    sq: int,
    sk: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (sk - sq)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # tile-level skip: fully-masked tiles do no work
    first_q = iq * bq + (sk - sq)
    last_q = first_q + bq - 1
    first_k, last_k = ik * bk, ik * bk + bk - 1
    live = True
    if causal:
        live = jnp.asarray(last_q >= first_k)
    if window > 0:
        live = jnp.logical_and(live, jnp.asarray(first_q - last_k < window))

    @pl.when(live)
    def _tile():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                   # [BQ, BK]
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                         # [BQ, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                      # [BQ, BK]
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "scale", "block_q", "block_k",
        "interpret",
    ),
)
def flash_attention(
    q: jax.Array,            # [B, Sq, H, D]
    k: jax.Array,            # [B, Sk, K, D]
    v: jax.Array,            # [B, Sk, K, D]
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    assert h % kh == 0, (h, kh)
    group = h // kh
    scale = d**-0.5 if scale is None else scale
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, sq=sq, sk=sk,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, sq // bq, sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, d), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec(
                (1, bk, 1, d),
                lambda b, h, iq, ik, group=group: (b, ik, h // group, 0),
            ),
            pl.BlockSpec(
                (1, bk, 1, d),
                lambda b, h, iq, ik, group=group: (b, ik, h // group, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, bq, 1, d), lambda b, h, iq, ik: (b, iq, h, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
