"""Public jit'd wrappers for the Pallas kernels (the ops layer).

On CPU (this container) the kernels run with ``interpret=True``; on real TPU
hardware the same calls compile to Mosaic. ``INTERPRET`` defaults to True
when no TPU is present so examples/tests work everywhere.
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rglru import rglru_linear_scan as _rglru
from repro.kernels.rwkv6 import wkv6 as _wkv6
from repro.kernels.idm import idm_accel_kernel as _idm
from repro.kernels.idm import neighbor_kernel as _neighbor


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, block_q=128, block_k=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _flash(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


def rglru_linear_scan(a, x, h0, *, block_s=256, block_w=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _rglru(
        a, x, h0, block_s=block_s, block_w=block_w, interpret=interpret
    )


def wkv6(r, k, v, w, u, s0, *, block_s=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _wkv6(r, k, v, w, u, s0, block_s=block_s, interpret=interpret)


def idm_accel_kernel(pos, vel, lane, active, v0, T, a_max, b_comf, s0,
                     *, veh_len=4.5, block=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _idm(
        pos, vel, lane, active, v0, T, a_max, b_comf, s0,
        veh_len=veh_len, block=block, interpret=interpret,
    )


def neighbor_kernel(pos, lane, active, query_lanes,
                    *, veh_len=4.5, block=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _neighbor(
        pos, lane, active, query_lanes,
        veh_len=veh_len, block=block, interpret=interpret,
    )
