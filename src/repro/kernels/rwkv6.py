"""WKV6 recurrence (TPU Pallas): y_t = rᵗ(S + u⊙k vᵀ);  S ← w_t⊙S + k vᵀ.

Grid: ``(B, H, nS)`` with the sequence axis minor; the per-(batch,head) state
``S ∈ R^{K×V}`` (64×64 f32 = 16 KB) persists in VMEM scratch across sequence
tiles. Within a tile the recurrence steps sequentially (data-dependent decay
``w_t`` forbids a pure matmul form), but each step is a rank-1 update + a
matvec over the full K×V state — VPU-shaped work on resident data. The win
over XLA's lax.scan is locality: S never round-trips to HBM.

(The chunkwise-parallel formulation — intra-chunk attention + inter-chunk
state like FLA's — is the next optimization rung; noted in EXPERIMENTS.md.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(
    r_ref, k_ref, v_ref, w_ref,   # [1, BS, 1, K|V]
    u_ref,                        # [1, K]
    s0_ref,                       # [1, 1, K, V]
    y_ref,                        # [1, BS, 1, V]
    sout_ref,                     # [1, 1, K, V]
    s_ref,                        # scratch [K, V] f32
    *,
    bs: int,
):
    isq = pl.program_id(2)

    @pl.when(isq == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0, :].astype(jnp.float32)   # [BS, K]
    k = k_ref[0, :, 0, :].astype(jnp.float32)   # [BS, K]
    v = v_ref[0, :, 0, :].astype(jnp.float32)   # [BS, V]
    w = w_ref[0, :, 0, :].astype(jnp.float32)   # [BS, K]
    u = u_ref[0].astype(jnp.float32)            # [K]

    def step(t, S):
        kv = k[t][:, None] * v[t][None, :]      # [K, V] rank-1
        y = ((S + u[:, None] * kv) * r[t][:, None]).sum(axis=0)  # [V]
        y_ref[0, t, 0, :] = y.astype(y_ref.dtype)
        return w[t][:, None] * S + kv

    S = jax.lax.fori_loop(0, bs, step, s_ref[...])
    s_ref[...] = S

    @pl.when(isq == pl.num_programs(2) - 1)
    def _finish():
        sout_ref[0, 0] = s_ref[...].astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def wkv6(
    r: jax.Array,    # [B, S, H, K]
    k: jax.Array,    # [B, S, H, K]
    v: jax.Array,    # [B, S, H, V]
    w: jax.Array,    # [B, S, H, K]
    u: jax.Array,    # [H, K]
    s0: jax.Array,   # [B, H, K, V]
    *,
    block_s: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,H,V] in v.dtype, S_final [B,H,K,V] f32)."""
    b, s, h, kk = r.shape
    vv = v.shape[-1]
    bs = min(block_s, s)
    assert s % bs == 0, (s, bs)

    kernel = functools.partial(_wkv6_kernel, bs=bs)
    y, sf = pl.pallas_call(
        kernel,
        grid=(b, h, s // bs),
        in_specs=[
            pl.BlockSpec((1, bs, 1, kk), lambda b, h, isq: (b, isq, h, 0)),
            pl.BlockSpec((1, bs, 1, kk), lambda b, h, isq: (b, isq, h, 0)),
            pl.BlockSpec((1, bs, 1, vv), lambda b, h, isq: (b, isq, h, 0)),
            pl.BlockSpec((1, bs, 1, kk), lambda b, h, isq: (b, isq, h, 0)),
            pl.BlockSpec((1, kk), lambda b, h, isq: (h, 0)),
            pl.BlockSpec((1, 1, kk, vv), lambda b, h, isq: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, 1, vv), lambda b, h, isq: (b, isq, h, 0)),
            pl.BlockSpec((1, 1, kk, vv), lambda b, h, isq: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, vv), v.dtype),
            jax.ShapeDtypeStruct((b, h, kk, vv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((kk, vv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, sf
