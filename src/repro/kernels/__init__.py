"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel has: ``<name>.py`` (pl.pallas_call + BlockSpec), a jit'd wrapper
in ``ops.py``, and a pure-jnp oracle in ``ref.py``. Tests sweep shapes/dtypes
in ``interpret=True`` mode and assert_allclose against the oracles.

Kernels:
- ``flash_attention`` — tiled online-softmax attention (causal / sliding
  window / GQA / Gemma-2 logit softcap). TPU serving+prefill path.
- ``rglru``           — RG-LRU linear recurrence, sequence-tiled with carried
  state (recurrentgemma).
- ``rwkv6``           — WKV6 recurrence with data-dependent decay.
- ``idm``             — the simulator's per-lane lead-gap + IDM acceleration
  (the physics hot spot the paper delegates to Webots), plus the
  generalized multi-query lead+follower ``neighbor_kernel`` backing the
  neighborhood engine (``repro.core.neighbors``).
"""

from repro.kernels.ops import (
    flash_attention,
    rglru_linear_scan,
    wkv6,
    idm_accel_kernel,
    neighbor_kernel,
)

__all__ = [
    "flash_attention",
    "rglru_linear_scan",
    "wkv6",
    "idm_accel_kernel",
    "neighbor_kernel",
]
