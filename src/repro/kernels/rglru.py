"""RG-LRU linear recurrence (TPU Pallas): h_t = a_t ⊙ h_{t-1} + x_t.

Grid: ``(B, nW, nS)`` — width is tiled over the VPU lanes (BW = 128·k), the
sequence axis is the minor grid dim so the carried state ``h`` lives in VMEM
scratch across sequence tiles. Each step processes a ``[BS, BW]`` tile with
an in-VMEM ``fori_loop`` over BS (the recurrence is inherently sequential in
time, but all BW lanes advance in parallel — exactly the VPU shape).

Inputs are the *precomputed* per-step decays and gated inputs (the gate
matmuls upstream are MXU work XLA already handles well); this kernel covers
the part XLA does badly: the length-S sequential chain, fused in VMEM instead
of S round-trips to HBM. Also emits the final state (chunked prefill /
decode handoff).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(
    a_ref, x_ref, h0_ref,      # [1, BS, BW], [1, BS, BW], [1, BW]
    y_ref, hout_ref,           # [1, BS, BW], [1, BW]
    h_ref,                     # scratch [1, BW] f32
    *,
    bs: int,
):
    isq = pl.program_id(2)

    @pl.when(isq == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)   # [BS, BW]
    x = x_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + x[t]
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bs, step, h_ref[0])
    h_ref[0] = h

    @pl.when(isq == pl.num_programs(2) - 1)
    def _finish():
        hout_ref[...] = h_ref[...].astype(hout_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_s", "block_w", "interpret")
)
def rglru_linear_scan(
    a: jax.Array,    # [B, S, W] decay per step
    x: jax.Array,    # [B, S, W] gated inputs
    h0: jax.Array,   # [B, W] initial state
    *,
    block_s: int = 256,
    block_w: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (ys [B,S,W] in x.dtype, h_final [B,W] f32)."""
    b, s, w = x.shape
    bs = min(block_s, s)
    bw = min(block_w, w)
    assert s % bs == 0 and w % bw == 0, (s, bs, w, bw)

    kernel = functools.partial(_rglru_kernel, bs=bs)
    ys, hf = pl.pallas_call(
        kernel,
        grid=(b, w // bw, s // bs),
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda b, iw, isq: (b, isq, iw)),
            pl.BlockSpec((1, bs, bw), lambda b, iw, isq: (b, isq, iw)),
            pl.BlockSpec((1, bw), lambda b, iw, isq: (b, iw)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bw), lambda b, iw, isq: (b, isq, iw)),
            pl.BlockSpec((1, bw), lambda b, iw, isq: (b, iw)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, w), x.dtype),
            jax.ShapeDtypeStruct((b, w), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(a, x, h0)
    return ys, hf
