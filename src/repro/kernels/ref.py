"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0**30


def ref_attention(
    q: jax.Array,            # [B, Sq, H, D]
    k: jax.Array,            # [B, Sk, K, D]
    v: jax.Array,            # [B, Sk, K, D]
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
) -> jax.Array:
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = d**-0.5 if scale is None else scale
    qg = q.reshape(b, sq, kh, g, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    sk = k.shape[1]
    qpos = jnp.arange(sq)[:, None] + (sk - sq)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return o.reshape(b, sq, h, d)


def ref_rglru(
    a: jax.Array,    # [B, S, W] per-step decay in (0,1], f32
    x: jax.Array,    # [B, S, W] gated inputs
    h0: jax.Array,   # [B, W] initial state
) -> tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + x_t. Returns (ys [B,S,W], h_final [B,W])."""

    def step(h, inp):
        a_t, x_t = inp
        h = a_t * h + x_t
        return h, h

    af = a.astype(jnp.float32).swapaxes(0, 1)
    xf = x.astype(jnp.float32).swapaxes(0, 1)
    hf, ys = jax.lax.scan(step, h0.astype(jnp.float32), (af, xf))
    return ys.swapaxes(0, 1), hf


def ref_wkv6(
    r: jax.Array,    # [B, S, H, K]
    k: jax.Array,    # [B, S, H, K]
    v: jax.Array,    # [B, S, H, V]
    w: jax.Array,    # [B, S, H, K] per-step decay in (0,1)
    u: jax.Array,    # [H, K] bonus
    s0: jax.Array,   # [B, H, K, V] initial state
) -> tuple[jax.Array, jax.Array]:
    """y_t = rᵗ(S + u⊙k vᵀ); S ← w⊙S + k vᵀ. Returns (y, S_final)."""

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    seq = tuple(
        z.swapaxes(0, 1).astype(jnp.float32) for z in (r, k, v, w)
    )
    S, ys = jax.lax.scan(step, s0.astype(jnp.float32), seq)
    return ys.swapaxes(0, 1), S


def ref_idm_accel(
    pos: jax.Array,     # [N]
    vel: jax.Array,     # [N]
    lane: jax.Array,    # [N] i32
    active: jax.Array,  # [N] bool
    v0: jax.Array, T: jax.Array, a_max: jax.Array,
    b_comf: jax.Array, s0: jax.Array,
    veh_len: float,
) -> jax.Array:
    """Same-lane lead search + IDM acceleration (simulator hot spot)."""
    INF = 1e9
    n = pos.shape[0]
    dpos = pos[None, :] - pos[:, None]
    eye = jnp.eye(n, dtype=bool)
    ahead = (
        (lane[None, :] == lane[:, None])
        & active[None, :] & active[:, None] & ~eye & (dpos > 0)
    )
    lead_d = jnp.where(ahead, dpos, INF)
    lead_idx = jnp.argmin(lead_d, axis=1)
    has_lead = jnp.any(ahead, axis=1)
    gap = jnp.where(has_lead, jnp.min(lead_d, axis=1) - veh_len, INF)
    v_lead = jnp.where(has_lead, vel[lead_idx], 0.0)
    dv = jnp.where(has_lead, vel - v_lead, 0.0)

    gap = jnp.maximum(gap, 0.1)
    s_star = s0 + jnp.maximum(
        0.0, vel * T + vel * dv / (2.0 * jnp.sqrt(a_max * b_comf))
    )
    return a_max * (
        1.0 - (vel / jnp.maximum(v0, 0.1)) ** 4 - (s_star / gap) ** 2
    )
